"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Time-boxed for CPU: models are
reduced-size; the trends (memory reduction %, speedup, accuracy ordering,
communities) are what reproduce the paper's tables.

  fig2_layer_convergence   CKA-proxy per-layer convergence ordering (Fig. 2)
  tab1_fl_accuracy         SmartFreeze vs baselines accuracy (Figs. 7-8/Tab. I)
  fig10_memory             Eq.(4) per-stage memory reduction (Fig. 10, 82%)
  tab2_pace_ablation       block perturbation vs naive schedules (Tab. II)
  fig9_rlcd                RL-CD community quality + convergence (Fig. 9)
  speedup_time_model       stage FLOPs speedup (paper: up to 2.02x)
  kernels_microbench       Pallas kernels (interpret) vs jnp oracle timing
  round_engine             fused+cached round engine vs seed sequential path
                           (us/round per stage; emits BENCH_round_engine.json)
  selector_scale           vectorized population selector vs list-based path
                           (N up to 100k) + in-graph compressed fused round
                           (emits BENCH_selector_scale.json; BENCH_SMOKE=1
                           for the N=1k CI smoke)
  cache_quant              memory-tiered feature cache: bytes + us/round per
                           tier, fleet admission f32-only vs ladder, f32 vs
                           int8 accuracy (emits BENCH_cache_quant.json)
  shard_scale              sharded cohort execution: rounds/s at client-axis
                           device counts {1,2,4,8} (forced host devices; run
                           as its own process) + sharded==dense aggregate
                           assert (emits BENCH_shard_scale.json)
  fault_tolerance          accuracy + freeze schedule at {0,10,30}% faulty
                           clients, defenses on vs off; defended 30% within
                           ~2 points of clean, defenses-off diverges (emits
                           BENCH_fault_tolerance.json)
  kernel_hotpaths          Pallas hot-path kernels vs lax references: fused
                           int8-dequant GEMM + sparse cohort scatter-add,
                           us/call + max err + compressed-round use_pallas
                           parity (emits BENCH_kernel_hotpaths.json;
                           BENCH_SMOKE=1 for the CI smoke)

Run everything: ``python benchmarks/run.py``; or name a subset:
``python benchmarks/run.py round_engine fig10_memory``.
"""
import json
import sys, os, time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, n=3):
    fn()
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6


# ---------------------------------------------------------------------------


def fig2_layer_convergence():
    """Per-layer convergence rates: front layers stabilize first (Fig. 2).

    Proxy: per-block perturbation of a centrally trained tiny CNN — earlier
    stages' perturbation drops below threshold earlier than later stages'."""
    import jax, jax.numpy as jnp
    from repro.core.pace import PaceController
    from repro.data.synthetic import SyntheticVision
    from repro.models.cnn import CNN, CNNConfig
    from repro.optim import apply_updates, sgd

    sv = SyntheticVision(num_classes=4, image_size=16)
    data = sv.sample(512, seed=1)
    cfg = CNNConfig("m", "resnet", stage_sizes=(1, 1, 1),
                    stage_channels=(8, 16, 32), num_classes=4)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    opt = sgd(0.05)
    ost = opt.init(params)
    ctrls = {s: PaceController(window_q=3, smooth_h=3, min_rounds=1)
             for s in range(3)}

    @jax.jit
    def step(p, st, ost, batch):
        (l, st2), g = jax.value_and_grad(model.loss, has_aux=True)(p, st, batch)
        ups, ost2 = opt.update(g, ost, p)
        return apply_updates(p, ups), st2, ost2, l

    t0 = time.time()
    for r in range(30):
        idx = np.random.RandomState(r).choice(512, 64, replace=False)
        batch = {"x": jnp.asarray(data["x"][idx]), "y": jnp.asarray(data["y"][idx])}
        params, state, ost, _ = step(params, state, ost, batch)
        for s in range(3):
            ctrls[s].observe(params["stages"][f"stage{s}"])

    finals = [round(ctrls[s]._smoothed[-1], 3) for s in range(3)]
    _row("fig2_layer_convergence", (time.time() - t0) * 1e6,
         f"final_perturbation_per_stage={finals};"
         f"front_most_converged={finals[0] <= max(finals)}")


def tab1_fl_accuracy(rounds=12):
    """SmartFreeze vs AllSmall/ExclusiveFL/HeteroFL/TiFL/Oort/DepthFL."""
    import jax, jax.numpy as jnp
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl import baselines as B
    from repro.fl.client import make_client_fleet
    from repro.fl.server import SmartFreezeServer
    from repro.models.cnn import CNN, CNNConfig

    sv = SyntheticVision(num_classes=8, image_size=16)
    train = sv.sample(2000, seed=1)
    test = sv.sample(400, seed=2)
    parts = dirichlet_partition(train["y"], 16, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="high", seed=0)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1), stage_channels=(12, 24),
                    num_classes=8)
    # paper setting: the FULL model does NOT fit most clients; stages do.
    from repro.fl.baselines import full_model_memory
    from repro.models.cnn import CNN as _CNN
    full_mem = full_model_memory(_CNN(cfg), 32)
    mem_rng = np.random.RandomState(7)
    for c in clients:
        c.memory_bytes = full_mem * mem_rng.choice(
            [0.35, 0.5, 0.7, 0.9], p=[0.3, 0.3, 0.25, 0.15])

    def eval_fn(model, p, s):
        logits, _ = model.apply(p, s, jnp.asarray(test["x"]), train=False)
        return float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())

    t0 = time.time()
    results = {}
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    # accuracy-TREND benchmark: run the sequential path (fused=False) — it
    # skips the fused engine's per-cohort-shape compiles, which dominate at
    # this tiny scale; round_engine is the perf benchmark for the fused path
    srv = SmartFreezeServer(model, clients, clients_per_round=5, batch_size=32,
                            rounds_per_stage=rounds // 2, fused=False,
                            pace_kwargs=dict(min_rounds=3, mu=2,
                                             slope_lambda=3e-2))
    out = srv.run(params, state)
    results["smartfreeze"] = round(eval_fn(model, out["params"], out["state"]), 3)

    for name, fn in [("allsmall", B.run_allsmall),
                     ("exclusivefl", B.run_exclusivefl),
                     ("heterofl", B.run_heterofl),
                     ("oort", B.run_oort),
                     ("tifl", B.run_tifl),
                     ("depthfl", B.run_depthfl)]:
        out = fn(cfg, clients, rounds=rounds, batch_size=32,
                 clients_per_round=5, fused=False)
        if out.get("inoperative"):
            results[name] = "NA(inoperative)"
        else:
            results[name] = round(eval_fn(out["model"], out["params"],
                                          out["state"]), 3)
    _row("tab1_fl_accuracy", (time.time() - t0) * 1e6,
         str(results).replace(",", ";"))


def fig10_memory():
    """Eq.(4) per-stage memory vs full-model training, LM archs."""
    from repro import configs
    from repro.core.memory_model import (full_model_memory_bytes,
                                         stage_memory_bytes)

    t0 = time.time()
    out = []
    for arch, batch, seq in [("llama3-8b", 8, 4096), ("qwen2-72b", 8, 4096),
                             ("xlstm-350m", 8, 4096)]:
        cfg = configs.get(arch)
        full = full_model_memory_bytes(cfg, batch=batch, seq=seq)["total"]
        stages = [stage_memory_bytes(cfg, s, batch=batch, seq=seq)["total"]
                  for s in range(cfg.num_freeze_blocks)]
        avg_red = 1 - np.mean(stages) / full
        out.append(f"{arch}:avg_reduction={avg_red:.0%}")
    _row("fig10_memory", (time.time() - t0) * 1e6, ";".join(out))


def tab2_pace_ablation(rounds=16):
    """Block perturbation freezing vs (b) front-loaded and (c) naive equal."""
    import jax, jax.numpy as jnp
    from repro.core.pace import front_loaded_schedule, naive_equal_schedule
    from repro.data.partition import iid_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.server import SmartFreezeServer
    from repro.models.cnn import CNN, CNNConfig

    sv = SyntheticVision(num_classes=6, image_size=16)
    train = sv.sample(1500, seed=1)
    test = sv.sample(300, seed=2)
    parts = iid_partition(train["y"], 12, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1), stage_channels=(12, 24),
                    num_classes=6)

    def eval_fn(model, p, s):
        logits, _ = model.apply(p, s, jnp.asarray(test["x"]), train=False)
        return float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())

    t0 = time.time()
    res = {}
    for name, sched, pace in [
        ("with_bp", None, dict(min_rounds=5, mu=2, slope_lambda=6e-3)),
        ("b_front_loaded", front_loaded_schedule(rounds, 2), {}),
        ("c_naive_equal", naive_equal_schedule(rounds, 2), {}),
    ]:
        model = CNN(cfg)
        params, state = model.init(jax.random.PRNGKey(0))
        srv = SmartFreezeServer(model, clients, clients_per_round=5,
                                batch_size=32, rounds_per_stage=rounds // 2,
                                fused=False,  # trend bench: skip fused compiles
                                pace_kwargs=pace or dict(min_rounds=999))
        out = srv.run(params, state, schedule=sched, total_rounds=rounds)
        res[name] = round(eval_fn(model, out["params"], out["state"]), 3)
    _row("tab2_pace_ablation", (time.time() - t0) * 1e6,
         str(res).replace(",", ";"))


def fig9_rlcd():
    """RL-CD community detection on a planted non-IID fleet."""
    from repro.core.selector import rlcd_communities
    from repro.core.selector.louvain import louvain
    from repro.core.selector.similarity import similarity_matrix

    rng = np.random.RandomState(0)
    vecs = {}
    for g in range(4):
        proto = np.zeros(64)
        proto[g * 16:(g + 1) * 16] = 1.0
        for i in range(5):
            noise = 0.4 if i >= 3 else 0.05  # weak members per community
            vecs[g * 5 + i] = proto * (0.4 if i >= 3 else 1.0) + rng.randn(64) * noise
    W = similarity_matrix(vecs)
    t0 = time.time()
    comms_l = louvain(np.maximum(W, 0))
    comms_r = rlcd_communities(W)
    us = (time.time() - t0) * 1e6

    def purity(comms):
        good = 0
        for c in comms:
            if len({i // 5 for i in c}) == 1:
                good += len(c)
        return good / 20

    _row("fig9_rlcd", us,
         f"louvain_comms={len(comms_l)};rlcd_comms={len(comms_r)};"
         f"louvain_purity={purity(comms_l):.2f};rlcd_purity={purity(comms_r):.2f}")


def speedup_time_model():
    """Eq.(5)-(7): per-stage FLOPs speedup vs full training (paper: 2.02x)."""
    from repro import configs
    from repro.core.time_model import stage_speedup

    t0 = time.time()
    out = []
    for arch in ["llama3-8b", "deepseek-v2-236b", "zamba2-7b"]:
        cfg = configs.get(arch)
        sp = [round(stage_speedup(cfg, s, batch=1, seq=4096), 2)
              for s in range(cfg.num_freeze_blocks)]
        out.append(f"{arch}:mean={np.mean(sp):.2f}x;max={max(sp):.2f}x")
    _row("speedup_time_model", (time.time() - t0) * 1e6, ";".join(out))


def kernels_microbench():
    """Pallas kernels (interpret mode) vs jnp oracle — correctness check."""
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention_fwd

    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 256, 4, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 256, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 256, 2, 32), jnp.float32)
    us_k = _timeit(lambda: flash_attention_fwd(
        q, k, v, causal=True, block_q=128, block_k=128,
        interpret=True).block_until_ready(), n=2)
    us_r = _timeit(lambda: ref.flash_attention_ref(
        q, k, v, causal=True).block_until_ready(), n=2)
    err = float(np.abs(np.asarray(
        flash_attention_fwd(q, k, v, causal=True, block_q=128, block_k=128,
                            interpret=True))
        - np.asarray(ref.flash_attention_ref(q, k, v, causal=True))).max())
    _row("kernels_microbench", us_k,
         f"flash_interp_vs_ref_err={err:.1e};ref_us={us_r:.0f}"
         f";note=interpret-mode correctness (perf target is TPU)")


def round_engine(rounds=4):
    """Fused+cached round engine vs the seed's sequential/recompute path.

    Times one simulated federated round per stage in both modes (after a
    compile warmup round), checks cached-vs-recompute logits equivalence on
    BOTH freezing backends, and writes BENCH_round_engine.json so the perf
    trajectory is tracked from this PR on."""
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.core import freezing
    from repro.core import freezing_cnn as fz
    from repro.data.partition import iid_partition
    from repro.data.synthetic import SyntheticVision, make_lm_batch
    from repro.fl.client import make_client_fleet
    from repro.fl.engine import RoundEngine
    from repro.models.cnn import CNN, CNNConfig
    from repro.models.transformer import build
    from repro.optim import sgd

    sv = SyntheticVision(num_classes=8, image_size=16)
    train = sv.sample(576, seed=1)
    parts = iid_partition(train["y"], 6, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    by_id = {c.client_id: c for c in clients}
    sel = [c.client_id for c in clients]
    # 4-stage ResNet: the final stage's frozen prefix is 3/4 of the network —
    # the regime progressive training spends most wall-clock in (paper §IV)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1, 1, 1),
                    stage_channels=(8, 16, 32, 64), num_classes=8)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    n_stages = len(cfg.stage_sizes)
    bs = 16

    def make_engine(stage, frozen, fused):
        cached_loss = feature_fn = None
        if stage > 0:
            cached_loss = fz.cnn_cached_stage_loss_fn(model, stage)
            feature_fn = lambda x: fz.cnn_prefix_features(model, frozen, state,
                                                          x, stage)
        return RoundEngine(loss_fn=fz.cnn_stage_loss_fn(model, stage),
                           optimizer=sgd(0.05), frozen=frozen,
                           cached_loss_fn=cached_loss, feature_fn=feature_fn,
                           batch_size=bs, local_epochs=1, fused=fused)

    per_stage = []
    for stage in range(n_stages):
        frozen, active = fz.init_cnn_stage_active(model, params, stage,
                                                  jax.random.PRNGKey(1))
        row = {"stage": stage}
        for mode, fused in (("seed_sequential", False), ("fused_cached", True)):
            engine = make_engine(stage, frozen, fused)
            cache = {cid: True for cid in sel} if (fused and stage > 0) else {}
            a, st = active, state  # both modes start from the stage-start state
            a, st, _ = engine.run_round(by_id, sel, a, st, 0,
                                        use_cache=cache)  # warmup
            t0 = time.time()
            for r in range(1, rounds + 1):
                a, st, _ = engine.run_round(by_id, sel, a, st, r,
                                            use_cache=cache)
            jax.tree.leaves(a)[0].block_until_ready()
            row[f"{mode}_us"] = (time.time() - t0) / rounds * 1e6
        row["speedup"] = row["seed_sequential_us"] / row["fused_cached_us"]
        per_stage.append(row)
        # model growth: later stages' frozen prefixes use the trained weights
        # and BN running stats (what SmartFreezeServer itself threads forward)
        params = fz.merge_cnn_params(model, params, stage, a)
        state = st

    # cached vs recompute logits equivalence (fp32), CNN backend
    frozen, active = fz.init_cnn_stage_active(model, params, n_stages - 1,
                                              jax.random.PRNGKey(1))
    x = jnp.asarray(train["x"][:32])
    feats = fz.cnn_prefix_features(model, frozen, state, x, n_stages - 1)
    l_cached, _ = fz.cnn_stage_forward_from_features(model, active, state,
                                                     feats, n_stages - 1)
    l_full, _ = fz.cnn_stage_forward(model, frozen, active, state, x,
                                     n_stages - 1)
    cnn_err = float(np.abs(np.asarray(l_cached, np.float32)
                           - np.asarray(l_full, np.float32)).max())
    cnn_ok = bool(np.allclose(np.asarray(l_cached, np.float32),
                              np.asarray(l_full, np.float32),
                              rtol=1e-5, atol=1e-5))

    # ... and LM backend (reduced llama, final stage)
    lcfg = configs.get("llama3-8b").reduced(num_layers=4, num_freeze_blocks=2)
    lm = build(lcfg)
    lparams = lm.init(jax.random.PRNGKey(0))
    plan = freezing.make_stage_plan(lcfg, 1)
    lfrozen, lactive = freezing.init_stage_active(lm, lparams, plan,
                                                  jax.random.PRNGKey(1))
    batch = {k: jnp.asarray(v) for k, v in make_lm_batch(lcfg, 2, 32).items()}
    h0, aux0 = freezing.stage_prefix_features(lm, lfrozen, lactive, batch, plan)
    hc, wc, _ = freezing.stage_forward_from_features(lm, lactive, h0, aux0,
                                                     plan, remat=False)
    hf, wf, _ = freezing.stage_forward(lm, lfrozen, lactive, batch, plan,
                                       remat=False)
    lm_lc = np.asarray(hc @ wc.astype(hc.dtype), np.float32)
    lm_lf = np.asarray(hf @ wf.astype(hf.dtype), np.float32)
    lm_err = float(np.abs(lm_lc - lm_lf).max())
    lm_ok = bool(np.allclose(lm_lc, lm_lf, rtol=2e-2, atol=2e-2))  # bf16

    out = {"rounds_timed": rounds, "clients": len(sel),
           "per_stage": per_stage,
           "cnn_logits_allclose": cnn_ok, "cnn_logits_max_err": cnn_err,
           "lm_logits_allclose": lm_ok, "lm_logits_max_err": lm_err}
    path = os.path.join(os.path.dirname(__file__), "BENCH_round_engine.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    final = per_stage[-1]
    _row("round_engine", final["fused_cached_us"],
         ";".join(f"stage{r['stage']}:seq={r['seed_sequential_us']:.0f}us;"
                  f"fused={r['fused_cached_us']:.0f}us;"
                  f"speedup={r['speedup']:.2f}x" for r in per_stage)
         + f";cnn_allclose={cnn_ok};lm_allclose={lm_ok}")


def selector_scale():
    """Population-scale selection + in-graph compressed uplink (PR 2).

    Part 1 — selector: N in {1k, 10k, 100k} synthetic clients with 64
    planted communities. Times one ``select`` call (Eqs. 11-14 + community
    round-robin) for (a) the list-based ``ParticipantSelector`` in its
    server configuration (communities fitted — this path is quadratic in N
    from the per-member ``set(elig)`` pool rebuild), (b) the same selector
    with no communities (its fastest configuration), and (c) the
    ``VectorizedSelector`` over a device-resident ``ClientPopulation``.
    Cross-checks vectorized == list picks at N=1k with epsilon=0 first.

    Part 2 — compressed round: fused CNN round at ratio {dense, 0.1, 1.0};
    ratio=1.0 must be allclose to the dense Eq. 1 aggregate, ratio=0.1
    should stay within ~1.2x of the dense round's wall clock (the top-k +
    scatter adds run inside the same dispatch).

    Writes benchmarks/BENCH_selector_scale.json. BENCH_SMOKE=1 limits to
    N=1k and one timed round (the CI smoke configuration).
    """
    import jax, jax.numpy as jnp
    from repro.core.selector import (ClientInfo, ClientPopulation,
                                     ParticipantSelector, VectorizedSelector)
    from repro.data.partition import iid_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.engine import RoundEngine
    from repro.models.cnn import CNN, CNNConfig
    from repro.optim import sgd

    smoke = bool(int(os.environ.get("BENCH_SMOKE", "0")))
    ns = (1000,) if smoke else (1000, 10_000, 100_000)
    k, n_comm = 64, 64
    time_fn = lambda ci: ci.num_samples / ci.capability

    def build(n, seed=0):
        rng = np.random.RandomState(seed)
        mem = rng.choice([1.0, 2.0, 4.0, 8.0], size=n) * 2**30
        cap = rng.choice([1e9, 2.5e9, 5e9], size=n)
        samp = rng.randint(32, 512, size=n)
        loss = rng.rand(n).astype(np.float64)
        comm = rng.randint(0, n_comm, size=n)
        infos = {i: ClientInfo(i, float(mem[i]), float(cap[i]), int(samp[i]),
                               float(loss[i])) for i in range(n)}
        communities = [np.flatnonzero(comm == c).tolist()
                       for c in range(n_comm)]
        pop = ClientPopulation.from_infos(infos, community_id=comm,
                                          n_communities=n_comm)
        return infos, communities, pop

    # --- correctness cross-check (epsilon=0 -> identical picks) ---
    infos, communities, pop = build(1000)
    ls = ParticipantSelector(epsilon=0.0, seed=7)
    ls._communities = communities
    vs = VectorizedSelector(epsilon=0.0, seed=7)
    vs._communities = communities
    picks_equal = all(
        ls.select(infos, k, mem_required=1.5 * 2**30, stage_time_fn=time_fn)
        == vs.select(infos, k, mem_required=1.5 * 2**30, stage_time_fn=time_fn)
        for _ in range(3))

    def timeit_rounds(fn, rounds):
        fn(0)  # warmup (jit compile / first-touch)
        t0 = time.time()
        for r in range(1, rounds + 1):
            fn(r)
        return (time.time() - t0) / rounds * 1e6

    rows = []
    for n in ns:
        infos, communities, pop = build(n)
        mem_req = 1.5 * 2**30
        sel_v = VectorizedSelector(epsilon=0.2, seed=0)
        v_us = timeit_rounds(
            lambda r: sel_v.select_arrays(pop, k, mem_required=mem_req,
                                          round_idx=r), 1 if smoke else 5)
        sel_nc = ParticipantSelector(epsilon=0.2, seed=0)
        nc_us = timeit_rounds(
            lambda r: sel_nc.select(infos, k, mem_required=mem_req,
                                    stage_time_fn=time_fn),
            1 if smoke else 3)
        sel_c = ParticipantSelector(epsilon=0.2, seed=0)
        sel_c._communities = communities
        c_rounds = 1 if (smoke or n >= 100_000) else 2
        c_us = timeit_rounds(
            lambda r: sel_c.select(infos, k, mem_required=mem_req,
                                   stage_time_fn=time_fn), c_rounds)
        rows.append({
            "n": n, "k": k, "n_communities": n_comm,
            "vectorized_us": v_us,
            "list_no_communities_us": nc_us,
            "list_with_communities_us": c_us,
            "speedup_vs_list": c_us / v_us,
            "speedup_vs_list_no_communities": nc_us / v_us,
        })

    # --- fused compressed round vs dense ---
    sv = SyntheticVision(num_classes=8, image_size=16)
    train = sv.sample(384, seed=1)
    parts = iid_partition(train["y"], 6, seed=0)
    fleet = make_client_fleet(train, parts, scenario="low", seed=0)
    by_id = {c.client_id: c for c in fleet}
    sel = [c.client_id for c in fleet]
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1),
                    stage_channels=(12, 24), num_classes=8)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))

    def full_loss(p, frozen_unused, st, batch):
        return model.loss(p, st, batch, train=True)

    def round_us(ratio, rounds):
        eng = RoundEngine(loss_fn=full_loss, optimizer=sgd(0.05),
                          batch_size=16, local_epochs=1,
                          compress_ratio=ratio)
        a, st = eng.run_round(by_id, sel, params, state, 0)[:2]  # warmup
        t0 = time.time()
        for r in range(1, rounds + 1):
            a, st, _ = eng.run_round(by_id, sel, a, st, r)
        jax.tree.leaves(a)[0].block_until_ready()
        return (time.time() - t0) / rounds * 1e6, eng

    rnds = 1 if smoke else 4
    dense_us, eng_d = round_us(None, rnds)
    c01_us, eng_c = round_us(0.1, rnds)
    c1_us, _ = round_us(1.0, rnds)
    # ratio=1.0 == dense Eq. 1 aggregate (one fresh round, same start state)
    e1 = RoundEngine(loss_fn=full_loss, optimizer=sgd(0.05), batch_size=16,
                     local_epochs=1, compress_ratio=1.0)
    e0 = RoundEngine(loss_fn=full_loss, optimizer=sgd(0.05), batch_size=16,
                     local_epochs=1)
    p1 = e1.run_round(by_id, sel, params, state, 0)[0]
    p0 = e0.run_round(by_id, sel, params, state, 0)[0]
    ratio1_ok = all(np.allclose(np.asarray(a, np.float32),
                                np.asarray(b, np.float32),
                                rtol=2e-4, atol=2e-4)
                    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p0)))

    out = {
        "smoke": smoke, "picks_equal_eps0": bool(picks_equal),
        "selector": rows,
        "compressed_round": {
            "clients": len(sel), "dense_us": dense_us,
            "ratio0.1_us": c01_us, "ratio1.0_us": c1_us,
            "overhead_at_0.1": c01_us / dense_us,
            "ratio1_allclose_dense": bool(ratio1_ok),
            "uplink_bytes_dense": eng_d.last_uplink_bytes,
            "uplink_bytes_0.1": eng_c.last_uplink_bytes,
        },
    }
    path = os.path.join(os.path.dirname(__file__),
                        "BENCH_selector_scale.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    # correctness flags gate the (CI smoke) run — timings are reported, not
    # asserted, but the equivalence contracts must hold
    assert picks_equal, "vectorized selector diverged from the list path"
    assert ratio1_ok, "compressed round at ratio=1.0 != dense Eq. 1"
    last = rows[-1]
    _row("selector_scale", last["vectorized_us"],
         ";".join(f"N={r['n']}:list={r['list_with_communities_us']:.0f}us;"
                  f"list_nc={r['list_no_communities_us']:.0f}us;"
                  f"vec={r['vectorized_us']:.0f}us;"
                  f"speedup={r['speedup_vs_list']:.0f}x" for r in rows)
         + f";picks_equal_eps0={picks_equal}"
         + f";compress_overhead@0.1={c01_us / dense_us:.2f}x"
         + f";ratio1_allclose={ratio1_ok}")


def cache_quant(rounds=10):
    """Memory-tiered frozen-prefix activation cache (PR 4).

    On a straggler-heavy heterogeneous fleet whose memories straddle the
    tier thresholds, reports: feature-cache bytes per tier (f32/fp16/int8,
    honest stored-dtype accounting incl. int8 scale vectors), the share of
    the fleet admitted to cached mode under f32-only vs ladder admission
    (Eq. 12 per tier), cached-round us at f32 vs int8, virtual-clock time
    for a short SmartFreeze run under both admission policies
    (cache_time_scale on: admitted clients skip the prefix forward), and
    the final-accuracy delta between f32-cached and int8-cached stage
    training. Asserts the PR's acceptance contract: >=3.5x int8 cache
    reduction, accuracy within 1 point, strictly more clients admitted by
    the ladder than by f32-only admission. Writes
    benchmarks/BENCH_cache_quant.json. BENCH_SMOKE=1 trims rounds.
    """
    import jax, jax.numpy as jnp
    from repro.core import freezing_cnn as fz
    from repro.core.memory_model import (CACHE_TIER_DTYPES, CACHE_TIERS,
                                         cnn_stage_memory_bytes)
    from repro.data.partition import iid_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.engine import RoundEngine
    from repro.fl.server import SmartFreezeServer
    from repro.models.cnn import CNN, CNNConfig
    from repro.optim import sgd

    smoke = bool(int(os.environ.get("BENCH_SMOKE", "0")))
    rounds = 4 if smoke else rounds
    sv = SyntheticVision(num_classes=8, image_size=16)
    train = sv.sample(1536, seed=1)
    test = sv.sample(384, seed=2)
    parts = iid_partition(train["y"], 12, seed=0)
    clients = make_client_fleet(train, parts, scenario="high", seed=0)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1),
                    stage_channels=(12, 24), num_classes=8)
    model = CNN(cfg)
    stage = 1
    # straggler-heavy: a quarter of the fleet 20x slower (paper §V)
    for c in clients:
        c.capability = 0.05e9 if c.client_id % 4 == 0 else 1e9
    # memories straddle the tier ladder: 1/4 full f32 cache, 1/4 fp16-only,
    # 1/4 int8-only, 1/4 stage-only (cache declined even at int8). The
    # stragglers (i % 4 == 0) are exactly the int8-only quartile, so ladder
    # admission accelerates the clients that gate the sync barrier while
    # f32-only admission leaves them on full prefix recompute.
    need = lambda c, dt: cnn_stage_memory_bytes(
        model, stage, 32, 16, cache_samples=c.num_samples, cache_dtype=dt)
    base = cnn_stage_memory_bytes(model, stage, 32, 16)
    for i, c in enumerate(clients):
        c.memory_bytes = [need(c, "int8"), need(c, "float32"),
                          need(c, "float16"), base][i % 4] * 1.02

    t0 = time.time()
    srv_f32 = SmartFreezeServer(model, clients, cache_tiers=("f32",))
    srv_all = SmartFreezeServer(model, clients, cache_tiers="all")
    admitted = {
        "f32_only": sum(1 for t in srv_f32._cache_plan(stage).values() if t),
        "ladder": sum(1 for t in srv_all._cache_plan(stage).values() if t),
        "fleet": len(clients),
    }
    ladder_plan = srv_all._cache_plan(stage)
    tier_counts = {t: sum(1 for v in ladder_plan.values() if v == t)
                   for t in CACHE_TIERS}

    # --- cache bytes + us/round per tier (same fully-admitted cohort) ---
    params, state = model.init(jax.random.PRNGKey(0))
    frozen, active = fz.init_cnn_stage_active(model, params, stage,
                                              jax.random.PRNGKey(1))
    by_id = {c.client_id: c for c in clients}
    sel = [c.client_id for c in clients[:6]]

    def make_engine():
        return RoundEngine(
            loss_fn=fz.cnn_stage_loss_fn(model, stage), optimizer=sgd(0.05),
            frozen=frozen,
            cached_loss_fn=fz.cnn_cached_stage_loss_fn(model, stage),
            feature_fn=lambda x: fz.cnn_prefix_features(model, frozen, state,
                                                        x, stage),
            batch_size=32, local_epochs=1, fused=not smoke)

    cache_bytes, us_per_round, final_acc = {}, {}, {}
    timed = 1 if smoke else max(rounds // 2, 2)
    for tier in CACHE_TIERS:
        eng = make_engine()
        cache = {cid: tier for cid in sel}
        a, st = active, state
        a, st, _ = eng.run_round(by_id, sel, a, st, 0, use_cache=cache)
        cache_bytes[tier] = eng.cache_nbytes()
        t1 = time.time()
        for r in range(1, timed + 1):
            a, st, _ = eng.run_round(by_id, sel, a, st, r, use_cache=cache)
        jax.tree.leaves(a)[0].block_until_ready()
        us_per_round[tier] = (time.time() - t1) / timed * 1e6
        for r in range(timed + 1, rounds + 1):  # finish the training budget
            a, st, _ = eng.run_round(by_id, sel, a, st, r, use_cache=cache)
        merged = fz.merge_cnn_params(model, params, stage, a)
        logits, _ = model.apply(merged, st, jnp.asarray(test["x"]),
                                train=False)
        final_acc[tier] = float((jnp.argmax(logits, -1)
                                 == jnp.asarray(test["y"])).mean())
    reduction = cache_bytes["f32"] / cache_bytes["int8"]
    acc_delta = abs(final_acc["f32"] - final_acc["int8"])

    # --- admission reaches the virtual clock (cache_time_scale on): the
    # sync barrier waits on the 20x stragglers, and only ladder admission
    # gets their prefix out of the per-minibatch loop ---
    from repro.fl.sim import FleetTimeModel
    virtual_s = {}
    for name, tiers in (("f32_only", ("f32",)), ("ladder", "all")):
        tm = FleetTimeModel.from_clients(clients, flops_per_sample=5e7)
        srv = SmartFreezeServer(model, clients, clients_per_round=6,
                                batch_size=32, seed=0, fused=False,
                                cache_tiers=tiers, cache_time_scale=True,
                                time_model=tm,
                                pace_kwargs=dict(min_rounds=99))
        out = srv.run(params, state, schedule=[1, rounds])
        virtual_s[name] = out["virtual_time"]
    assert virtual_s["ladder"] < virtual_s["f32_only"], virtual_s

    out = {"smoke": smoke, "rounds": rounds,
           "cache_bytes": cache_bytes,
           "int8_reduction_x": reduction,
           "admitted": admitted,
           "ladder_tier_counts": tier_counts,
           "cached_pct": {k: admitted[k] / admitted["fleet"]
                          for k in ("f32_only", "ladder")},
           "us_per_round": us_per_round,
           "final_acc": final_acc,
           "acc_delta_f32_vs_int8": acc_delta,
           "virtual_s": virtual_s}
    path = os.path.join(os.path.dirname(__file__), "BENCH_cache_quant.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    # the PR's acceptance contract
    assert reduction >= 3.5, f"int8 cache only {reduction:.2f}x smaller"
    assert acc_delta <= 0.01, (final_acc["f32"], final_acc["int8"])
    assert admitted["ladder"] > admitted["f32_only"], admitted
    _row("cache_quant", us_per_round["int8"],
         f"cache_f32={cache_bytes['f32']};cache_int8={cache_bytes['int8']};"
         f"reduction={reduction:.2f}x;"
         f"admitted_f32only={admitted['f32_only']}/{admitted['fleet']};"
         f"admitted_ladder={admitted['ladder']}/{admitted['fleet']};"
         f"acc_f32={final_acc['f32']:.3f};acc_int8={final_acc['int8']:.3f};"
         f"virt_f32only={virtual_s['f32_only']:.1f}s;"
         f"virt_ladder={virtual_s['ladder']:.1f}s")


def sim_scale(rounds=18):
    """Virtual-time simulation core (fl/sim.py): one FederatedLoop under the
    three aggregation policies on a straggler-heavy fleet.

    Reports, per policy: wall us/round, total *virtual* seconds simulated,
    virtual-vs-wall speedup (how much faster the simulator runs than the
    fleet it models), and final accuracy. Asserts the paper's qualitative
    claim — the deadline policy beats the sync barrier on virtual-clock time
    while staying within one accuracy point — plus the vectorized time
    kernel's O(N) scaling at N=100k. Writes benchmarks/BENCH_sim_scale.json.
    BENCH_SMOKE=1 limits rounds (the CI smoke configuration).
    """
    import jax, jax.numpy as jnp
    from repro.data.partition import iid_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.server import FedAvgServer
    from repro.fl.sim import (AsyncBufferedAggregation, DeadlineAggregation,
                              FleetTimeModel)
    from repro.models.cnn import CNN, CNNConfig

    smoke = bool(int(os.environ.get("BENCH_SMOKE", "0")))
    rounds = 6 if smoke else rounds
    sv = SyntheticVision(num_classes=4, image_size=16)
    train = sv.sample(1600, seed=1)
    test = sv.sample(400, seed=2)
    # IID equal shards: stragglers differ in CAPABILITY, not data volume, so
    # the deadline's drops cost redundancy, not coverage (paper §V straggler
    # scenario)
    parts = iid_partition(train["y"], 16, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    # straggler-heavy: a quarter of the fleet is 20x slower
    for c in clients:
        c.capability = 0.05e9 if c.client_id % 4 == 0 else 1e9
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1),
                    stage_channels=(12, 24), num_classes=4)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    # Eq. 6 with a VGG-ish ~50 MFLOPs/sample local step so virtual seconds
    # are device-realistic (the default |D|/c heuristic is selection-scaled)
    flops_per_sample = 5e7

    def eval_fn(p, s):
        logits, _ = model.apply(p, s, jnp.asarray(test["x"]), train=False)
        return float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())

    policies = [("sync", "sync"),
                ("deadline", DeadlineAggregation(factor=1.5)),
                ("async", AsyncBufferedAggregation(buffer_size=4,
                                                   concurrency=8))]
    results = {}
    for name, pol in policies:
        tm = FleetTimeModel.from_clients(clients,
                                         flops_per_sample=flops_per_sample)
        srv = FedAvgServer(model, clients, clients_per_round=8, batch_size=32,
                           seed=0, fused=False, aggregation=pol,
                           time_model=tm)
        t0 = time.time()
        out = srv.run(params, state, rounds=rounds)
        wall = time.time() - t0
        results[name] = {
            "wall_s": wall, "wall_us_per_round": wall / rounds * 1e6,
            "rounds_per_s": rounds / wall,
            "virtual_s": out["virtual_time"],
            "virtual_vs_wall": out["virtual_time"] / wall,
            "final_acc": eval_fn(out["params"], out["state"]),
            "mean_cohort": float(np.mean([len(r.selected)
                                          for r in out["history"]])),
        }

    # vectorized time kernel at population scale (pure O(N) array work)
    rng = np.random.RandomState(0)
    n = 10_000 if smoke else 100_000

    class _Stub:
        def __init__(self, cid, ns, cap):
            self.client_id, self.num_samples, self.capability = cid, ns, cap
            self.link_rate = 1e6

    fleet = [_Stub(i, int(s), float(c)) for i, (s, c) in enumerate(
        zip(rng.randint(32, 512, n), rng.choice([1e9, 5e9], n)))]
    tm_big = FleetTimeModel.from_clients(fleet,
                                         flops_per_sample=flops_per_sample)
    tm_big.payload_bytes = 1e6
    tm_big.population_times(0).block_until_ready()  # compile
    kernel_us = _timeit(lambda: tm_big.population_times(1).block_until_ready(),
                        n=3)

    dl, sy = results["deadline"], results["sync"]
    out = {"smoke": smoke, "rounds": rounds, "clients": len(clients),
           "policies": results, "time_kernel_n": n,
           "time_kernel_us": kernel_us,
           "deadline_speedup_vs_sync": sy["virtual_s"] / dl["virtual_s"],
           "acc_gap_sync_vs_deadline": abs(sy["final_acc"] - dl["final_acc"])}
    path = os.path.join(os.path.dirname(__file__), "BENCH_sim_scale.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    # the acceptance contract: deadline beats sync on the virtual clock on a
    # straggler-heavy scenario with accuracy within one point
    assert dl["virtual_s"] < sy["virtual_s"], (dl["virtual_s"], sy["virtual_s"])
    assert abs(sy["final_acc"] - dl["final_acc"]) <= 0.011, \
        (sy["final_acc"], dl["final_acc"])
    _row("sim_scale", results["sync"]["wall_us_per_round"],
         ";".join(f"{k}:virt={v['virtual_s']:.1f}s;wall={v['wall_s']:.1f}s;"
                  f"vxw={v['virtual_vs_wall']:.0f}x;acc={v['final_acc']:.3f}"
                  for k, v in results.items())
         + f";deadline_speedup={out['deadline_speedup_vs_sync']:.2f}x"
         + f";time_kernel_N{n}={kernel_us:.0f}us")


def shard_scale(rounds=6):
    """Sharded cohort execution (ISSUE 5): rounds/s vs client-axis devices.

    Forces 8 host devices (``--xla_force_host_platform_device_count=8``,
    set before jax initializes — run this benchmark as its own process, as
    the CI step does) and times the fused SmartFreeze-stage round at a
    FIXED 8-client cohort with the client axis sharded over {1, 2, 4, 8}
    devices. Device count 1 is the exact single-device path (no shard_map);
    every sharded count is asserted allclose (f32) against its aggregate —
    params, BN state, and per-client losses. Writes
    benchmarks/BENCH_shard_scale.json. BENCH_SMOKE=1 trims the timed
    rounds. On the CPU host-device backend the curve measures dispatch +
    partitioning overhead, not real parallel FLOPs — the trend worth
    tracking is that sharding stays within noise of single-device at tiny
    scale (the crossover needs real accelerators).
    """
    if "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()
    import jax
    from repro.core import freezing_cnn as fz
    from repro.data.partition import iid_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.engine import RoundEngine
    from repro.launch.mesh import make_client_mesh
    from repro.models.cnn import CNN, CNNConfig
    from repro.optim import sgd

    smoke = bool(int(os.environ.get("BENCH_SMOKE", "0")))
    rounds = 2 if smoke else rounds
    n_dev = len(jax.devices())
    counts = [c for c in (1, 2, 4, 8) if c <= n_dev]
    if counts != [1, 2, 4, 8]:
        print(f"# shard_scale: only {n_dev} device(s) visible (jax was "
              "already initialized?) — timing the available counts", flush=True)

    sv = SyntheticVision(num_classes=8, image_size=16)
    train = sv.sample(768, seed=1)
    parts = iid_partition(train["y"], 8, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    by_id = {c.client_id: c for c in clients}
    sel = sorted(by_id)                          # fixed 8-client cohort
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1),
                    stage_channels=(12, 24), num_classes=8)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    stage = 1
    frozen, active = fz.init_cnn_stage_active(model, params, stage,
                                              jax.random.PRNGKey(1))

    def make_engine(mesh):
        return RoundEngine(
            loss_fn=fz.cnn_stage_loss_fn(model, stage), optimizer=sgd(0.05),
            frozen=frozen, batch_size=32, local_epochs=1, mesh=mesh)

    def tree_close(a, b):
        return all(np.allclose(np.asarray(x, np.float32),
                               np.asarray(y, np.float32),
                               rtol=3e-4, atol=3e-4)
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    # dense single-device reference aggregate for the equality contract
    ref_p, ref_s, ref_l = make_engine(None).run_round(by_id, sel, active,
                                                      state, 0)
    rows = []
    for d in counts:
        eng = make_engine(make_client_mesh(d) if d > 1 else None)
        a, st, l = eng.run_round(by_id, sel, active, state, 0)  # warm + check
        agg_ok = (tree_close(a, ref_p) and tree_close(st, ref_s)
                  and all(abs(l[c] - ref_l[c]) < 1e-3 for c in sel))
        assert agg_ok, f"{d}-way sharded aggregate != dense single-device"
        t0 = time.time()
        for r in range(1, rounds + 1):
            a, st, _ = eng.run_round(by_id, sel, a, st, r)
        jax.tree.leaves(a)[0].block_until_ready()
        dt = (time.time() - t0) / rounds
        rows.append({"devices": d, "rounds_per_s": 1.0 / dt,
                     "us_per_round": dt * 1e6, "agg_allclose": agg_ok})

    out = {"smoke": smoke, "rounds_timed": rounds, "clients": len(sel),
           "visible_devices": n_dev, "per_device_count": rows}
    if counts == [1, 2, 4, 8]:
        path = os.path.join(os.path.dirname(__file__),
                            "BENCH_shard_scale.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
    else:
        # don't clobber the tracked {1,2,4,8} perf-trajectory artifact with
        # a degraded sweep (jax initialized before the forced-host-device
        # flag could land — e.g. the all-benchmarks mode)
        print("# shard_scale: incomplete device sweep — "
              "BENCH_shard_scale.json not written", flush=True)
    _row("shard_scale", rows[-1]["us_per_round"],
         ";".join(f"d={r['devices']}:rps={r['rounds_per_s']:.2f};"
                  f"allclose={r['agg_allclose']}" for r in rows))


def fault_tolerance(rounds=16):
    """Fault-tolerant rounds (ISSUE 7): accuracy + freeze schedule under
    injected faults.

    Arms: faulty-client fraction {0%, 10%, 30%} x defenses {on, off}, same
    deterministic FaultInjector schedule (nan / amplified corruption +
    mid-round crashes) in both arms at each fraction. Defenses = in-graph
    update screening + non-finite pace/loss guards + freeze rollback.
    Contract: the defended 30%-faulty run lands within ~2 accuracy points
    of fault-free, freezes no block on a poisoned perturbation window, and
    the defenses-off arm diverges (non-finite params, chance accuracy) —
    documented, not repaired. Writes benchmarks/BENCH_fault_tolerance.json.
    BENCH_SMOKE=1 trims rounds. Sequential path (fused=False): trend bench,
    same rationale as tab1.
    """
    import jax, jax.numpy as jnp
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.faults import FaultInjector
    from repro.fl.server import SmartFreezeServer
    from repro.models.cnn import CNN, CNNConfig

    smoke = bool(int(os.environ.get("BENCH_SMOKE", "0")))
    if smoke:
        rounds = 8
    sv = SyntheticVision(num_classes=6, image_size=16)
    train = sv.sample(1500, seed=1)
    test = sv.sample(300, seed=2)
    parts = dirichlet_partition(train["y"], 12, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1),
                    stage_channels=(12, 24), num_classes=6)

    def eval_fn(model, p, s):
        logits, _ = model.apply(p, s, jnp.asarray(test["x"]), train=False)
        return float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())

    t0 = time.time()
    arms = []
    for frac in (0.0, 0.1, 0.3):
        for defended in (True, False):
            if frac == 0.0 and not defended:
                continue   # the zero-fault bit-identity pair is a unit test
            model = CNN(cfg)
            params, state = model.init(jax.random.PRNGKey(0))
            inj = FaultInjector(p_fault=frac, seed=23,
                                kinds=("nan", "amplify", "crash")) \
                if frac else None
            kw = (dict(screen_updates=True, freeze_rollback=True)
                  if defended else {})
            srv = SmartFreezeServer(model, clients, clients_per_round=5,
                                    batch_size=32,
                                    rounds_per_stage=rounds // 2,
                                    fused=False, faults=inj,
                                    pace_kwargs=dict(min_rounds=3, mu=2,
                                                     slope_lambda=3e-2),
                                    **kw)
            out = srv.run(params, state, total_rounds=rounds)
            stages = [r.stage for r in srv.history]
            finite = bool(all(np.isfinite(np.asarray(x)).all()
                              for x in jax.tree.leaves(out["params"])))
            arms.append({
                "fault_frac": frac, "defended": defended,
                "final_acc": round(eval_fn(model, out["params"],
                                           out["state"]), 4),
                "final_loss": float(srv.history[-1].loss),
                "freeze_schedule": [stages.count(s)
                                    for s in sorted(set(stages))],
                "frozen_rounds": [r.round_idx for r in srv.history
                                  if r.frozen],
                "screened_updates": int(sum(len(r.screened)
                                            for r in srv.history)),
                "rollbacks": int(getattr(srv, "rollbacks", 0)),
                "finite_params": finite,
            })
    by = {(a["fault_frac"], a["defended"]): a for a in arms}
    clean = by[(0.0, True)]
    gap30 = clean["final_acc"] - by[(0.3, True)]["final_acc"]
    undef = by[(0.3, False)]
    diverged = (not undef["finite_params"]
                or undef["final_acc"] < clean["final_acc"] - 0.10)
    out = {"rounds": rounds, "smoke": smoke, "arms": arms,
           "defended_gap_30pct": round(gap30, 4),
           "undefended_30pct_diverged": diverged}
    path = os.path.join(os.path.dirname(__file__),
                        "BENCH_fault_tolerance.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    assert by[(0.3, True)]["finite_params"]
    assert diverged, "defenses-off arm failed to diverge at 30% faults"
    # smoke trims rounds below what a stable accuracy gap needs; the smoke
    # gate checks plumbing (finite + divergence), the full run the contract
    gap_tol = 0.25 if smoke else 0.05
    assert gap30 <= gap_tol, f"defended 30% arm lost {gap30:.3f} accuracy"
    _row("fault_tolerance", (time.time() - t0) * 1e6,
         ";".join(f"f={a['fault_frac']:g}:def={int(a['defended'])}:"
                  f"acc={a['final_acc']:.3f}:scr={a['screened_updates']}:"
                  f"fin={int(a['finite_params'])}" for a in arms)
         + f";gap30={gap30:.3f};undef_diverged={diverged}")


def kernel_hotpaths():
    """Pallas hot-path kernels vs their lax references (ISSUE 10).

    The two roofline-ordered additions to the fused round: the int8-dequant
    GEMM that feeds tiered cache features to the first consumer matmul with
    the scales applied in-register, and the sparse cohort scatter-add that
    folds K clients' compressed uplinks in one kernel launch. Reports
    us/call for kernel (interpret mode on CPU — a CORRECTNESS number, the
    perf target is TPU Mosaic) vs reference, max abs error on the same
    inputs, and the end-to-end use_pallas=True vs False parity of a fused
    compressed round. Writes benchmarks/BENCH_kernel_hotpaths.json (the CI
    artifact). BENCH_SMOKE=1 trims shapes and reps.
    """
    import jax, jax.numpy as jnp
    from repro.fl import quant
    from repro.fl.engine import make_fused_round
    from repro.kernels import ops, ref
    from repro.optim import sgd

    smoke = bool(int(os.environ.get("BENCH_SMOKE", "0")))
    reps = 2 if smoke else 3
    rng = np.random.RandomState(0)

    # --- fused int8-dequant GEMM ---
    M, K, N = (64, 128, 64) if smoke else (192, 384, 128)
    x = jnp.asarray(rng.randn(M, K) * 2.0, jnp.float32)
    q, scale = quant.quantize_int8(x)
    w = jnp.asarray(rng.randn(K, N) * 0.3, jnp.float32)
    run_k = jax.jit(lambda: ops.dequant_matmul(
        q, scale, w, block_m=64, block_n=64, block_k=64))
    run_r = jax.jit(lambda: ref.dequant_matmul_ref(q, scale, w))
    us_k = _timeit(lambda: run_k().block_until_ready(), n=reps)
    us_r = _timeit(lambda: run_r().block_until_ready(), n=reps)
    gemm_err = float(np.abs(np.asarray(run_k()) - np.asarray(run_r())).max())
    gemm_ref_mag = float(np.abs(np.asarray(run_r())).max())
    gemm_ok = gemm_err <= 1e-4 * max(1.0, gemm_ref_mag)

    # --- sparse cohort scatter-add ---
    Kc, topk, L = (4, 32, 1024) if smoke else (8, 64, 4096)
    idx = jnp.asarray(rng.randint(0, L, size=(Kc, topk)), jnp.int32)
    vals = jnp.asarray(rng.randn(Kc, topk), jnp.float32)
    wts = jnp.asarray(rng.rand(Kc) + 0.1, jnp.float32)
    agg_k = jax.jit(lambda: ops.sparse_cohort_add(idx, vals, wts, L))
    agg_r = jax.jit(lambda: ref.sparse_cohort_add_ref(idx, vals, wts, L))
    us_ak = _timeit(lambda: agg_k().block_until_ready(), n=reps)
    us_ar = _timeit(lambda: agg_r().block_until_ready(), n=reps)
    agg_err = float(np.abs(np.asarray(agg_k()) - np.asarray(agg_r())).max())
    agg_ok = agg_err <= 1e-5 * max(1.0, float(np.abs(np.asarray(agg_r())).max()))

    # --- end-to-end: fused compressed round, use_pallas vs XLA default ---
    D, H, C, Kcl, nb, bs = 12, 8, 4, 3, 2, 8
    params = {"w1": jnp.asarray(rng.randn(D, H) * 0.3, jnp.float32),
              "w2": jnp.asarray(rng.randn(H, C) * 0.3, jnp.float32)}
    batches = {"x": jnp.asarray(rng.randn(Kcl, nb, bs, D), jnp.float32),
               "y": jnp.asarray(rng.randint(0, C, size=(Kcl, nb, bs)),
                                jnp.int32)}
    nb_live = jnp.full((Kcl,), nb, jnp.int32)
    wcl = jnp.ones((Kcl,), jnp.float32) / Kcl
    residuals = jax.tree.map(
        lambda l: jnp.zeros((Kcl, l.size), jnp.float32), params)

    def loss_fn(p, frozen, st, b):
        h = jnp.tanh(b["x"] @ p["w1"])
        logp = jax.nn.log_softmax(h @ p["w2"])
        return -jnp.mean(jnp.take_along_axis(logp, b["y"][:, None], 1)), st

    def round_us(use_pallas):
        fn = make_fused_round(loss_fn, sgd(0.05), compress_ratio=0.3,
                              unroll=True, use_pallas=use_pallas)
        out = fn(params, {}, {}, batches, nb_live, wcl, residuals)
        us = _timeit(lambda: jax.tree.leaves(
            fn(params, {}, {}, batches, nb_live, wcl, residuals)[0]
        )[0].block_until_ready(), n=reps)
        return us, out

    us_rp, out_p = round_us(True)
    us_rx, out_x = round_us(False)
    round_ok = all(np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-5, atol=1e-5)
                   for a, b in zip(jax.tree.leaves(out_p[0]),
                                   jax.tree.leaves(out_x[0])))

    out = {"smoke": smoke,
           "dequant_matmul": {"shape": [M, K, N], "pallas_us": us_k,
                              "ref_us": us_r, "max_err": gemm_err,
                              "allclose": gemm_ok},
           "sparse_cohort_add": {"K": Kc, "topk": topk, "length": L,
                                 "pallas_us": us_ak, "ref_us": us_ar,
                                 "max_err": agg_err, "allclose": agg_ok},
           "compressed_round": {"pallas_us": us_rp, "xla_us": us_rx,
                                "params_allclose": round_ok},
           "note": "interpret-mode timings on CPU gate correctness, not perf"}
    path = os.path.join(os.path.dirname(__file__),
                        "BENCH_kernel_hotpaths.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    assert gemm_ok, f"dequant GEMM err {gemm_err:.2e} vs mag {gemm_ref_mag:.2e}"
    assert agg_ok, f"sparse fold err {agg_err:.2e}"
    assert round_ok, "use_pallas compressed round != XLA round"
    _row("kernel_hotpaths", us_k,
         f"gemm[{M}x{K}x{N}]:pallas={us_k:.0f}us;ref={us_r:.0f}us;"
         f"err={gemm_err:.1e};agg[K{Kc}xk{topk}->L{L}]:pallas={us_ak:.0f}us;"
         f"ref={us_ar:.0f}us;err={agg_err:.1e};"
         f"round:pallas={us_rp:.0f}us;xla={us_rx:.0f}us;"
         f"parity={round_ok}")


BENCHES = {}


def main() -> None:
    BENCHES.update({f.__name__: f for f in (
        fig10_memory, speedup_time_model, fig9_rlcd, fig2_layer_convergence,
        kernels_microbench, round_engine, tab2_pace_ablation, tab1_fl_accuracy,
        selector_scale, sim_scale, cache_quant, shard_scale,
        fault_tolerance, kernel_hotpaths)})
    names = sys.argv[1:] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown benchmark(s) {unknown}; "
                         f"choose from {list(BENCHES)}")
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main()
