"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Time-boxed for CPU: models are
reduced-size; the trends (memory reduction %, speedup, accuracy ordering,
communities) are what reproduce the paper's tables.

  fig2_layer_convergence   CKA-proxy per-layer convergence ordering (Fig. 2)
  tab1_fl_accuracy         SmartFreeze vs baselines accuracy (Figs. 7-8/Tab. I)
  fig10_memory             Eq.(4) per-stage memory reduction (Fig. 10, 82%)
  tab2_pace_ablation       block perturbation vs naive schedules (Tab. II)
  fig9_rlcd                RL-CD community quality + convergence (Fig. 9)
  speedup_time_model       stage FLOPs speedup (paper: up to 2.02x)
  kernels_microbench       Pallas kernels (interpret) vs jnp oracle timing
"""
import sys, os, time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, n=3):
    fn()
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6


# ---------------------------------------------------------------------------


def fig2_layer_convergence():
    """Per-layer convergence rates: front layers stabilize first (Fig. 2).

    Proxy: per-block perturbation of a centrally trained tiny CNN — earlier
    stages' perturbation drops below threshold earlier than later stages'."""
    import jax, jax.numpy as jnp
    from repro.core.pace import PaceController
    from repro.data.synthetic import SyntheticVision
    from repro.models.cnn import CNN, CNNConfig
    from repro.optim import apply_updates, sgd

    sv = SyntheticVision(num_classes=4, image_size=16)
    data = sv.sample(512, seed=1)
    cfg = CNNConfig("m", "resnet", stage_sizes=(1, 1, 1),
                    stage_channels=(8, 16, 32), num_classes=4)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    opt = sgd(0.05)
    ost = opt.init(params)
    ctrls = {s: PaceController(window_q=3, smooth_h=3, min_rounds=1)
             for s in range(3)}

    @jax.jit
    def step(p, st, ost, batch):
        (l, st2), g = jax.value_and_grad(model.loss, has_aux=True)(p, st, batch)
        ups, ost2 = opt.update(g, ost, p)
        return apply_updates(p, ups), st2, ost2, l

    t0 = time.time()
    for r in range(30):
        idx = np.random.RandomState(r).choice(512, 64, replace=False)
        batch = {"x": jnp.asarray(data["x"][idx]), "y": jnp.asarray(data["y"][idx])}
        params, state, ost, _ = step(params, state, ost, batch)
        for s in range(3):
            ctrls[s].observe(params["stages"][f"stage{s}"])

    finals = [round(ctrls[s]._smoothed[-1], 3) for s in range(3)]
    _row("fig2_layer_convergence", (time.time() - t0) * 1e6,
         f"final_perturbation_per_stage={finals};"
         f"front_most_converged={finals[0] <= max(finals)}")


def tab1_fl_accuracy(rounds=12):
    """SmartFreeze vs AllSmall/ExclusiveFL/HeteroFL/TiFL/Oort/DepthFL."""
    import jax, jax.numpy as jnp
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl import baselines as B
    from repro.fl.client import make_client_fleet
    from repro.fl.server import SmartFreezeServer
    from repro.models.cnn import CNN, CNNConfig

    sv = SyntheticVision(num_classes=8, image_size=16)
    train = sv.sample(2000, seed=1)
    test = sv.sample(400, seed=2)
    parts = dirichlet_partition(train["y"], 16, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="high", seed=0)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1), stage_channels=(12, 24),
                    num_classes=8)
    # paper setting: the FULL model does NOT fit most clients; stages do.
    from repro.fl.baselines import full_model_memory
    from repro.models.cnn import CNN as _CNN
    full_mem = full_model_memory(_CNN(cfg), 32)
    mem_rng = np.random.RandomState(7)
    for c in clients:
        c.memory_bytes = full_mem * mem_rng.choice(
            [0.35, 0.5, 0.7, 0.9], p=[0.3, 0.3, 0.25, 0.15])

    def eval_fn(model, p, s):
        logits, _ = model.apply(p, s, jnp.asarray(test["x"]), train=False)
        return float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())

    t0 = time.time()
    results = {}
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    srv = SmartFreezeServer(model, clients, clients_per_round=5, batch_size=32,
                            rounds_per_stage=rounds // 2,
                            pace_kwargs=dict(min_rounds=3, mu=2,
                                             slope_lambda=3e-2))
    out = srv.run(params, state)
    results["smartfreeze"] = round(eval_fn(model, out["params"], out["state"]), 3)

    for name, fn in [("allsmall", B.run_allsmall),
                     ("exclusivefl", B.run_exclusivefl),
                     ("heterofl", B.run_heterofl),
                     ("oort", B.run_oort),
                     ("tifl", B.run_tifl),
                     ("depthfl", B.run_depthfl)]:
        out = fn(cfg, clients, rounds=rounds, batch_size=32,
                 clients_per_round=5)
        if out.get("inoperative"):
            results[name] = "NA(inoperative)"
        else:
            results[name] = round(eval_fn(out["model"], out["params"],
                                          out["state"]), 3)
    _row("tab1_fl_accuracy", (time.time() - t0) * 1e6,
         str(results).replace(",", ";"))


def fig10_memory():
    """Eq.(4) per-stage memory vs full-model training, LM archs."""
    from repro import configs
    from repro.core.memory_model import (full_model_memory_bytes,
                                         stage_memory_bytes)

    t0 = time.time()
    out = []
    for arch, batch, seq in [("llama3-8b", 8, 4096), ("qwen2-72b", 8, 4096),
                             ("xlstm-350m", 8, 4096)]:
        cfg = configs.get(arch)
        full = full_model_memory_bytes(cfg, batch=batch, seq=seq)["total"]
        stages = [stage_memory_bytes(cfg, s, batch=batch, seq=seq)["total"]
                  for s in range(cfg.num_freeze_blocks)]
        avg_red = 1 - np.mean(stages) / full
        out.append(f"{arch}:avg_reduction={avg_red:.0%}")
    _row("fig10_memory", (time.time() - t0) * 1e6, ";".join(out))


def tab2_pace_ablation(rounds=16):
    """Block perturbation freezing vs (b) front-loaded and (c) naive equal."""
    import jax, jax.numpy as jnp
    from repro.core.pace import front_loaded_schedule, naive_equal_schedule
    from repro.data.partition import iid_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.server import SmartFreezeServer
    from repro.models.cnn import CNN, CNNConfig

    sv = SyntheticVision(num_classes=6, image_size=16)
    train = sv.sample(1500, seed=1)
    test = sv.sample(300, seed=2)
    parts = iid_partition(train["y"], 12, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1), stage_channels=(12, 24),
                    num_classes=6)

    def eval_fn(model, p, s):
        logits, _ = model.apply(p, s, jnp.asarray(test["x"]), train=False)
        return float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())

    t0 = time.time()
    res = {}
    for name, sched, pace in [
        ("with_bp", None, dict(min_rounds=5, mu=2, slope_lambda=6e-3)),
        ("b_front_loaded", front_loaded_schedule(rounds, 2), {}),
        ("c_naive_equal", naive_equal_schedule(rounds, 2), {}),
    ]:
        model = CNN(cfg)
        params, state = model.init(jax.random.PRNGKey(0))
        srv = SmartFreezeServer(model, clients, clients_per_round=5,
                                batch_size=32, rounds_per_stage=rounds // 2,
                                pace_kwargs=pace or dict(min_rounds=999))
        out = srv.run(params, state, schedule=sched, total_rounds=rounds)
        res[name] = round(eval_fn(model, out["params"], out["state"]), 3)
    _row("tab2_pace_ablation", (time.time() - t0) * 1e6,
         str(res).replace(",", ";"))


def fig9_rlcd():
    """RL-CD community detection on a planted non-IID fleet."""
    from repro.core.selector import rlcd_communities
    from repro.core.selector.louvain import louvain
    from repro.core.selector.similarity import similarity_matrix

    rng = np.random.RandomState(0)
    vecs = {}
    for g in range(4):
        proto = np.zeros(64)
        proto[g * 16:(g + 1) * 16] = 1.0
        for i in range(5):
            noise = 0.4 if i >= 3 else 0.05  # weak members per community
            vecs[g * 5 + i] = proto * (0.4 if i >= 3 else 1.0) + rng.randn(64) * noise
    W = similarity_matrix(vecs)
    t0 = time.time()
    comms_l = louvain(np.maximum(W, 0))
    comms_r = rlcd_communities(W)
    us = (time.time() - t0) * 1e6

    def purity(comms):
        good = 0
        for c in comms:
            if len({i // 5 for i in c}) == 1:
                good += len(c)
        return good / 20

    _row("fig9_rlcd", us,
         f"louvain_comms={len(comms_l)};rlcd_comms={len(comms_r)};"
         f"louvain_purity={purity(comms_l):.2f};rlcd_purity={purity(comms_r):.2f}")


def speedup_time_model():
    """Eq.(5)-(7): per-stage FLOPs speedup vs full training (paper: 2.02x)."""
    from repro import configs
    from repro.core.time_model import stage_speedup

    t0 = time.time()
    out = []
    for arch in ["llama3-8b", "deepseek-v2-236b", "zamba2-7b"]:
        cfg = configs.get(arch)
        sp = [round(stage_speedup(cfg, s, batch=1, seq=4096), 2)
              for s in range(cfg.num_freeze_blocks)]
        out.append(f"{arch}:mean={np.mean(sp):.2f}x;max={max(sp):.2f}x")
    _row("speedup_time_model", (time.time() - t0) * 1e6, ";".join(out))


def kernels_microbench():
    """Pallas kernels (interpret mode) vs jnp oracle — correctness check."""
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention_fwd

    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 256, 4, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 256, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 256, 2, 32), jnp.float32)
    us_k = _timeit(lambda: flash_attention_fwd(
        q, k, v, causal=True, block_q=128, block_k=128,
        interpret=True).block_until_ready(), n=2)
    us_r = _timeit(lambda: ref.flash_attention_ref(
        q, k, v, causal=True).block_until_ready(), n=2)
    err = float(np.abs(np.asarray(
        flash_attention_fwd(q, k, v, causal=True, block_q=128, block_k=128,
                            interpret=True))
        - np.asarray(ref.flash_attention_ref(q, k, v, causal=True))).max())
    _row("kernels_microbench", us_k,
         f"flash_interp_vs_ref_err={err:.1e};ref_us={us_r:.0f}"
         f";note=interpret-mode correctness (perf target is TPU)")


def main() -> None:
    print("name,us_per_call,derived")
    fig10_memory()
    speedup_time_model()
    fig9_rlcd()
    fig2_layer_convergence()
    kernels_microbench()
    tab2_pace_ablation()
    tab1_fl_accuracy()


if __name__ == "__main__":
    main()
