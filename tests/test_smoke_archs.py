"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import freezing
from repro.data.synthetic import make_lm_batch
from repro.models.transformer import build
from repro.optim import adamw

ARCHS = configs.names()


def _batch(cfg, B=2, S=32):
    return {k: jnp.asarray(v) for k, v in make_lm_batch(cfg, B, S).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = configs.get(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    S_out = batch["labels"].shape[1] if cfg.modality != "vision_stub" else 32
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = configs.get(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = freezing.make_stage_plan(cfg, None)  # full model step
    frozen, active = freezing.init_stage_active(model, params, plan,
                                                jax.random.PRNGKey(1))
    opt = adamw(3e-3)
    step = jax.jit(freezing.make_train_step(model, plan, opt, remat=False))
    state = freezing.TrainState(active, frozen, opt.init(active), jnp.int32(0))
    batch = _batch(cfg)
    losses = []
    for _ in range(5):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not configs.get(a).is_encoder_only])
def test_decode_step(arch):
    cfg = configs.get(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(batch=2, max_seq=16)
    logits, cache2 = model.decode_step(
        params, {"tokens": jnp.zeros((2, 1), jnp.int32)}, cache, jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
