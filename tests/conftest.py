import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # real hypothesis when available (declared in pyproject.toml)
    import hypothesis  # noqa: F401
except ImportError:  # hermetic/offline: deterministic seeded-sweep fallback
    from repro._compat import hypothesis_stub

    sys.modules["hypothesis"] = hypothesis_stub
    sys.modules["hypothesis.strategies"] = hypothesis_stub.strategies
