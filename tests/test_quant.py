"""Memory-tiered feature cache + bf16 mixed precision (PR 4).

Covers: int8 round-trip error bound, dtype-aware memory model + admission
ladder (host == vectorized kernel), tiered engine rounds (legacy-boolean
compatibility, int8-vs-f32 training parity within 1 accuracy point, fused ==
sequential), bf16 fused rounds allclose to f32 with f32 master params, the
single-jit ``weighted_avg`` fold's bit-identity to the seed loop, cache
state (tiers + quant scales) round-tripping through ``CheckpointManager``,
and bit-identical resume across a tier decision."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import freezing_cnn as fz
from repro.core.memory_model import (CACHE_TIER_DTYPES, CACHE_TIERS,
                                     cache_tier_ladder,
                                     cnn_feature_cache_bytes,
                                     cnn_stage_memory_bytes,
                                     feature_cache_bytes, stage_memory_bytes)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticVision
from repro.fl.client import fleet_population, make_client_fleet
from repro.fl.engine import RoundEngine, weighted_avg
from repro.fl.quant import (EncodedFeatures, decode_features, dequantize_int8,
                            encode_features, normalize_tier, quantize_int8)
from repro.fl.server import SmartFreezeServer
from repro.fl.sim import FleetTimeModel
from repro.models.cnn import CNN, CNNConfig
from repro.optim import sgd

TINY = CNNConfig("tiny_resnet", "resnet", stage_sizes=(1, 1),
                 stage_channels=(8, 16), num_classes=4)


@pytest.fixture(scope="module")
def world():
    sv = SyntheticVision(num_classes=4, image_size=16, seed=0)
    train = sv.sample(600, seed=1)
    test = sv.sample(200, seed=2)
    parts = dirichlet_partition(train["y"], 6, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    model = CNN(TINY)
    params, state = model.init(jax.random.PRNGKey(0))
    return train, test, clients, model, params, state


def _stage1_engine(model, frozen, state, *, fused=False, compute_dtype=None):
    return RoundEngine(
        loss_fn=fz.cnn_stage_loss_fn(model, 1), optimizer=sgd(0.05),
        frozen=frozen, cached_loss_fn=fz.cnn_cached_stage_loss_fn(model, 1),
        feature_fn=lambda x: fz.cnn_prefix_features(model, frozen, state, x, 1),
        batch_size=32, local_epochs=1, fused=fused,
        compute_dtype=compute_dtype)


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# quantization correctness
# ---------------------------------------------------------------------------


def test_int8_roundtrip_error_bound():
    """|dequant(quant(x)) - x| <= scale/2 elementwise, scale = amax/127 per
    (sample, channel) group — over shapes, magnitudes, and distributions."""
    rng = np.random.RandomState(0)
    shapes = [(8, 6, 6, 5), (3, 16, 16, 8), (4, 32, 12), (7, 9)]
    for i, shape in enumerate(shapes):
        for mag in (1e-3, 1.0, 1e4):
            x = (rng.randn(*shape) * mag).astype(np.float32)
            if i == 0:
                x[:, ..., 0] = 0.0  # an all-zero channel must not NaN
            q, s = quantize_int8(jnp.asarray(x))
            assert np.asarray(q).dtype == np.int8
            xr = np.asarray(dequantize_int8(q, s))
            bound = np.broadcast_to(np.asarray(s) / 2, x.shape)
            assert (np.abs(xr - x) <= bound + 1e-12 * mag).all(), shape
    # heavy-tailed: outliers set the scale but the bound still holds
    x = rng.standard_cauchy((6, 8, 8, 4)).astype(np.float32)
    q, s = quantize_int8(jnp.asarray(x))
    xr = np.asarray(dequantize_int8(q, s))
    assert (np.abs(xr - x) <= np.broadcast_to(np.asarray(s) / 2, x.shape)
            + 1e-9).all()


def test_encode_tiers_nbytes_and_decode():
    rng = np.random.RandomState(1)
    x = rng.randn(50, 8, 8, 16).astype(np.float32)
    f32 = encode_features(x, "f32")
    f16 = encode_features(x, "fp16")
    i8 = encode_features(x, "int8")
    assert f32.nbytes == x.nbytes
    assert f16.nbytes == x.nbytes // 2
    # int8 = values + per-(sample, channel) f32 scales: >= 3.5x smaller
    assert f32.nbytes / i8.nbytes >= 3.5
    np.testing.assert_array_equal(decode_features(f32), x)
    np.testing.assert_allclose(decode_features(f16), x, atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(decode_features(i8), x, atol=0.05, rtol=0.05)
    assert normalize_tier(True) == "f32" and normalize_tier(False) is None
    assert normalize_tier(np.bool_(True)) == "f32"
    with pytest.raises(ValueError):
        normalize_tier("int4")


# ---------------------------------------------------------------------------
# dtype-aware memory model + admission ladder
# ---------------------------------------------------------------------------


def test_memory_model_dtype_aware(world):
    _, _, _, model, _, _ = world
    f32 = cnn_feature_cache_bytes(model, 1, 500, 16, "float32")
    f16 = cnn_feature_cache_bytes(model, 1, 500, 16, "float16")
    i8 = cnn_feature_cache_bytes(model, 1, 500, 16, "int8")
    assert f32 > f16 > i8 > 0
    assert f32 / i8 >= 3.5         # 4x minus the f32 scale vectors
    assert f16 == f32 / 2
    # the stage hook prices the tier the same way
    base = cnn_stage_memory_bytes(model, 1, 32, 16)
    for dt, cb in (("float32", f32), ("float16", f16), ("int8", i8)):
        tot = cnn_stage_memory_bytes(model, 1, 32, 16, cache_samples=500,
                                     cache_dtype=dt)
        np.testing.assert_allclose(tot, base + cb)
    # LM twin
    lcfg = configs.get("llama3-8b").reduced(num_layers=4, num_freeze_blocks=2)
    lf32 = feature_cache_bytes(lcfg, 4096, "float32")
    li8 = feature_cache_bytes(lcfg, 4096, "int8", scale_vectors=32)
    assert lf32 / li8 >= 3.5
    lm = stage_memory_bytes(lcfg, 1, batch=2, seq=128, cache_tokens=4096,
                            cache_dtype="int8")
    assert lm["feature_cache"] == li8


def test_server_admission_ladder(world):
    _, _, clients, model, _, _ = world
    clients = [dataclasses.replace(c) for c in clients]
    need = lambda c, dt: cnn_stage_memory_bytes(
        model, 1, 32, 16, cache_samples=c.num_samples, cache_dtype=dt)
    base = cnn_stage_memory_bytes(model, 1, 32, 16)
    clients[0].memory_bytes = need(clients[0], "int8") + 1.0
    clients[1].memory_bytes = need(clients[1], "float16") + 1.0
    clients[2].memory_bytes = need(clients[2], "float32") + 1.0
    clients[3].memory_bytes = base + 1.0   # fits the stage but no cache
    srv = SmartFreezeServer(model, clients, cache_tiers="all")
    plan = srv._cache_plan(1)
    assert (plan[0], plan[1], plan[2], plan[3]) == ("int8", "fp16", "f32",
                                                    None)
    # default ladder is f32-only — exactly the pre-tier boolean gate
    srv_d = SmartFreezeServer(model, clients)
    plan_d = srv_d._cache_plan(1)
    assert plan_d[0] is None and plan_d[1] is None and plan_d[2] == "f32"
    assert srv_d._cache_plan(0) == {}
    with pytest.raises(ValueError, match="unknown cache tiers"):
        SmartFreezeServer(model, clients, cache_tiers=("int4",))
    # ladder helper is order-aware
    assert cache_tier_ladder(need(clients[0], "int8") + 1,
                             lambda t: need(clients[0],
                                            CACHE_TIER_DTYPES[t])) == "int8"


def test_vectorized_tier_admission_matches_host(world):
    from repro.core.selector.vectorized import assign_cache_tiers
    _, _, clients, model, _, _ = world
    clients = [dataclasses.replace(c) for c in clients]
    rng = np.random.RandomState(3)
    base = cnn_stage_memory_bytes(model, 1, 32, 16)
    for c in clients:  # memories scattered across all admission outcomes
        c.memory_bytes = base + float(rng.rand()) * 2.5 * \
            cnn_feature_cache_bytes(model, 1, c.num_samples, 16, "float32") \
            - float(rng.rand() < 0.25) * base
    srv = SmartFreezeServer(model, clients, cache_tiers="all")
    host_plan = srv._cache_plan(1)
    pop = fleet_population(clients)
    rates = [cnn_feature_cache_bytes(model, 1, 1, 16, CACHE_TIER_DTYPES[t])
             for t in CACHE_TIERS]
    idx = assign_cache_tiers(pop, base, rates)
    vec_plan = {int(cid): (CACHE_TIERS[i] if i >= 0 else None)
                for cid, i in zip(pop.client_ids, idx)}
    assert vec_plan == host_plan
    assert set(host_plan.values()) >= {"f32", None}  # scenario non-trivial


# ---------------------------------------------------------------------------
# tiered engine rounds
# ---------------------------------------------------------------------------


def test_legacy_bool_use_cache_is_f32_tier(world):
    _, _, clients, model, params, state = world
    by_id = {c.client_id: c for c in clients}
    frozen, active = fz.init_cnn_stage_active(model, params, 1,
                                              jax.random.PRNGKey(1))
    sel = [c.client_id for c in clients[:3]]
    a1, s1, l1 = _stage1_engine(model, frozen, state).run_round(
        by_id, sel, active, state, 0, use_cache={cid: True for cid in sel})
    a2, s2, l2 = _stage1_engine(model, frozen, state).run_round(
        by_id, sel, active, state, 0, use_cache={cid: "f32" for cid in sel})
    _tree_equal(a1, a2)
    _tree_equal(s1, s2)
    assert l1 == l2


def test_int8_cached_training_within_one_point_of_f32(world):
    """Multi-round stage-1 training on int8-cached features tracks the
    f32-cached path: final eval accuracy within 1 point (the satellite's
    tier-1-scale parity claim) and per-round losses stay close."""
    _, test, clients, model, params, state = world
    by_id = {c.client_id: c for c in clients}
    frozen, active = fz.init_cnn_stage_active(model, params, 1,
                                              jax.random.PRNGKey(1))
    sel = [c.client_id for c in clients[:4]]

    def run(tier, rounds=8):
        eng = _stage1_engine(model, frozen, state)
        a, st = active, state
        losses = []
        for r in range(rounds):
            a, st, l = eng.run_round(by_id, sel, a, st, r,
                                     use_cache={cid: tier for cid in sel})
            losses.append(float(np.mean(list(l.values()))))
        merged = fz.merge_cnn_params(model, params, 1, a)
        logits, _ = model.apply(merged, st, jnp.asarray(test["x"]),
                                train=False)
        acc = float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())
        return acc, losses

    acc_f32, loss_f32 = run("f32")
    acc_i8, loss_i8 = run("int8")
    assert abs(acc_f32 - acc_i8) <= 0.01, (acc_f32, acc_i8)
    np.testing.assert_allclose(loss_i8, loss_f32, rtol=0.05, atol=0.02)


def test_int8_fused_matches_sequential(world):
    _, _, clients, model, params, state = world
    by_id = {c.client_id: c for c in clients}
    frozen, active = fz.init_cnn_stage_active(model, params, 1,
                                              jax.random.PRNGKey(1))
    sel = [c.client_id for c in clients[:3]]
    cache = {cid: "int8" for cid in sel}
    a_f, s_f, l_f = _stage1_engine(model, frozen, state, fused=True) \
        .run_round(by_id, sel, active, state, 2, use_cache=cache)
    a_s, s_s, l_s = _stage1_engine(model, frozen, state, fused=False) \
        .run_round(by_id, sel, active, state, 2, use_cache=cache)
    for x, y in zip(jax.tree.leaves(a_f), jax.tree.leaves(a_s)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_cache_nbytes_reports_stored_dtype(world):
    _, _, clients, model, params, state = world
    by_id = {c.client_id: c for c in clients}
    frozen, _ = fz.init_cnn_stage_active(model, params, 1,
                                         jax.random.PRNGKey(1))
    c0 = clients[0]
    per_tier = {}
    for tier in CACHE_TIERS:
        eng = _stage1_engine(model, frozen, state)
        enc = eng.features_for(c0, tier)
        assert isinstance(enc, EncodedFeatures) and enc.tier == tier
        per_tier[tier] = eng.cache_nbytes()
        assert per_tier[tier] == enc.nbytes
    assert per_tier["fp16"] == per_tier["f32"] // 2
    assert per_tier["f32"] / per_tier["int8"] >= 3.5
    # exact accounting: int8 stores values + f32 scale vectors
    exp = c0.num_samples * 16 * 16 * 8 + c0.num_samples * 8 * 4
    assert per_tier["int8"] == exp


# ---------------------------------------------------------------------------
# bf16 mixed precision
# ---------------------------------------------------------------------------


def test_bf16_fused_round_loss_allclose_f32(world):
    _, _, clients, model, params, state = world
    by_id = {c.client_id: c for c in clients}
    frozen, active = fz.init_cnn_stage_active(model, params, 0,
                                              jax.random.PRNGKey(1))
    sel = [c.client_id for c in clients[:2]]

    def eng(cd):
        return RoundEngine(loss_fn=fz.cnn_stage_loss_fn(model, 0),
                           optimizer=sgd(0.05), frozen=frozen, batch_size=32,
                           local_epochs=1, fused=True, compute_dtype=cd)

    a_f, s_f, l_f = eng(None).run_round(by_id, sel, active, state, 0)
    a_b, s_b, l_b = eng("bfloat16").run_round(by_id, sel, active, state, 0)
    for cid in sel:
        np.testing.assert_allclose(l_b[cid], l_f[cid], rtol=2e-2, atol=2e-2)
    # master params / BN state keep their f32 dtypes, values track f32
    assert {str(x.dtype) for x in jax.tree.leaves(a_b)} == {"float32"}
    assert {str(x.dtype) for x in jax.tree.leaves(s_b)} == {"float32"}
    for x, y in zip(jax.tree.leaves(a_b), jax.tree.leaves(a_f)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=0.1,
                                   atol=0.05)


# ---------------------------------------------------------------------------
# weighted_avg: single-jit fold == seed loop, bitwise
# ---------------------------------------------------------------------------


def test_weighted_avg_bit_identical_to_seed_fold(world):
    _, _, _, model, params, state = world

    def seed_avg(trees, w):  # the pre-PR implementation, verbatim
        out = jax.tree.map(lambda x: x.astype(jnp.float32) * float(w[0]),
                           trees[0])
        for t, wi in zip(trees[1:], w[1:]):
            out = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32) * float(wi), out, t)
        return jax.tree.map(lambda a, r: a.astype(r.dtype), out, trees[0])

    rng = np.random.RandomState(0)
    for k in (1, 2, 5):
        trees = [jax.tree.map(
            lambda x: x + jnp.asarray(rng.randn(*x.shape), x.dtype), params)
            for _ in range(k)]
        w = rng.dirichlet(np.ones(k))         # float64, like the callers'
        _tree_equal(weighted_avg(trees, w), seed_avg(trees, w))
    # state trees (possibly empty dicts) go through the same path
    assert weighted_avg([{} for _ in range(3)], np.ones(3) / 3) == {}


# ---------------------------------------------------------------------------
# serialization: tiers + quant scales through CheckpointManager
# ---------------------------------------------------------------------------


def test_cache_state_roundtrip_through_checkpoint(world, tmp_path):
    from repro.checkpoint import CheckpointManager
    _, _, clients, model, params, state = world
    frozen, _ = fz.init_cnn_stage_active(model, params, 1,
                                         jax.random.PRNGKey(1))
    eng = _stage1_engine(model, frozen, state)
    eng.features_for(clients[0], "int8")
    eng.features_for(clients[1], "fp16")
    eng.features_for(clients[2], "f32")
    tree = eng.cache_state()
    mgr = CheckpointManager(str(tmp_path / "ck"), async_save=False)
    mgr.save(0, {"cache": tree})
    restored = mgr.restore()["tree"]["cache"]
    eng2 = _stage1_engine(model, frozen, state)
    eng2.load_cache_state(restored)
    assert eng2.cache_tiers() == eng.cache_tiers()
    assert eng2.cache_nbytes() == eng.cache_nbytes()
    for cid in (clients[0].client_id, clients[1].client_id,
                clients[2].client_id):
        a, b = eng._features[cid], eng2._features[cid]
        assert b.values.dtype == a.values.dtype   # int8/f16 survive the disk
        np.testing.assert_array_equal(a.values, b.values)
        if a.scale is not None:
            np.testing.assert_array_equal(a.scale, b.scale)


def test_resume_across_tier_decision_bit_identical(world, tmp_path):
    """Crash + resume mid-stage with a mixed-tier cohort (int8/fp16/f32 and
    declined clients): loss/selection/virtual-time series and final params
    must be bit-identical to the uninterrupted run."""
    from repro.checkpoint import CheckpointManager
    _, _, clients, model, params, state = world
    clients = [dataclasses.replace(c) for c in clients]
    need = lambda c, dt: cnn_stage_memory_bytes(
        model, 1, 32, 16, cache_samples=c.num_samples, cache_dtype=dt)
    clients[0].memory_bytes = need(clients[0], "int8") + 1.0
    clients[1].memory_bytes = need(clients[1], "float16") + 1.0
    clients[2].memory_bytes = need(clients[2], "float32") + 1.0
    kw = dict(clients_per_round=4, batch_size=32, rounds_per_stage=3, seed=0,
              fused=False, cache_tiers="all", cache_time_scale=True,
              pace_kwargs=dict(min_rounds=99))

    srv_a = SmartFreezeServer(model, clients, **kw)
    out_a = srv_a.run(params, state, total_rounds=6)
    assert {t for t in srv_a.cache_tier_plan.values()} >= {"int8", "fp16",
                                                           "f32"}

    mgr = CheckpointManager(str(tmp_path / "ck"), async_save=False)
    srv_b = SmartFreezeServer(model, clients, **kw)
    calls = {"n": 0}

    class Crash(Exception):
        pass

    def crashing_eval(p, s, stage):
        calls["n"] += 1
        if calls["n"] > 4:
            raise Crash()
        return 0.0

    with pytest.raises(Crash):
        srv_b.run(params, state, total_rounds=6, ckpt_manager=mgr,
                  ckpt_every=1, eval_fn=crashing_eval, eval_every=1)
    assert 0 < len(srv_b.history) < len(out_a["history"])

    srv_c = SmartFreezeServer(model, clients, **kw)
    out_c = srv_c.run(params, state, total_rounds=6, ckpt_manager=mgr,
                      ckpt_every=1, resume=True)
    combined = srv_b.history + out_c["history"]
    assert len(combined) == len(out_a["history"])
    for a, b in zip(out_a["history"], combined):
        assert a.selected == b.selected
        assert a.loss == b.loss, (a.round_idx, a.loss, b.loss)
        assert a.virtual_time == b.virtual_time
    _tree_equal(out_a["params"], out_c["params"])
    _tree_equal(out_a["state"], out_c["state"])


# ---------------------------------------------------------------------------
# tier admission reaches the virtual clock
# ---------------------------------------------------------------------------


def test_compute_scale_shrinks_cached_clients_time(world):
    from repro.core.time_model import (cnn_cached_compute_scale,
                                       lm_cached_compute_scale)
    _, _, clients, _, _, _ = world
    tm = FleetTimeModel.from_clients(clients)
    tm2 = tm.with_compute_scale({clients[0].client_id:
                                 cnn_cached_compute_scale(1)})
    t1 = tm.cohort_times([c.client_id for c in clients[:3]], 0)
    t2 = tm2.cohort_times([c.client_id for c in clients[:3]], 0)
    cid0 = clients[0].client_id
    np.testing.assert_allclose(t2[cid0], t1[cid0] * 0.75, rtol=1e-6)
    for c in clients[1:3]:
        assert t1[c.client_id] == t2[c.client_id]
    assert cnn_cached_compute_scale(0) == 1.0
    # deeper stages cache more of the forward
    assert cnn_cached_compute_scale(3) < cnn_cached_compute_scale(1) < 1.0
    lcfg = configs.get("llama3-8b").reduced(num_layers=4, num_freeze_blocks=2)
    s = lm_cached_compute_scale(lcfg, 1)
    assert 0.0 < s < 1.0
