"""Doctest gate for the documented public API (ISSUE 5 satellite).

Runs the ``>>>`` examples embedded in the three modules the architecture
docs lean on — ``fl/engine.py`` (``make_fused_round``), ``fl/sim.py``
(``FederatedLoop``), ``fl/quant.py`` (the tier ladder) — so the examples in
docs/ARCHITECTURE.md's reference modules can never rot. CI additionally
runs ``pytest --doctest-modules`` on the same files; this test keeps the
gate inside the plain tier-1 invocation.
"""
import doctest

import pytest

import repro.fl.engine
import repro.fl.quant
import repro.fl.sim


@pytest.mark.parametrize("module", [repro.fl.engine, repro.fl.sim,
                                    repro.fl.quant],
                         ids=lambda m: m.__name__)
def test_module_doctests(module):
    result = doctest.testmod(module, verbose=False)
    assert result.attempted > 0, f"{module.__name__} lost its examples"
    assert result.failed == 0, (f"{result.failed} doctest failure(s) in "
                                f"{module.__name__}")
