"""Roofline HLO parser: trip-count-weighted FLOPs/collectives must be exact
on canonical cases (scan, nested scan, sharded matmul with all-reduce).

Also documents WHY the parser exists: compiled.cost_analysis() counts while
bodies once (under-reporting scan-over-layers FLOPs by ~L x).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.roofline import (collective_bytes, cost_analysis_dict,
                                   hlo_weighted_costs, _parse_computations,
                                   _multipliers)


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_weighted_by_trip_count():
    def f(x, w):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 64), jnp.float32))
    w = hlo_weighted_costs(c.as_text())
    assert w["flops"] == 2 * 64 * 64 * 64 * 10
    # the raw cost_analysis under-reports (documented limitation)
    raw = cost_analysis_dict(c)["flops"]
    assert raw < w["flops"] / 5


def test_nested_scan_multipliers_compose():
    def f(x, w):
        def outer(h, _):
            def inner(hh, _):
                return hh @ w, None
            hh, _ = jax.lax.scan(inner, h, None, length=5)
            return hh, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 64), jnp.float32))
    w = hlo_weighted_costs(c.as_text())
    assert w["flops"] == 2 * 64 * 64 * 64 * 15


def test_computation_parser_handles_tuple_params():
    def f(x):
        def body(carry, _):
            h, i = carry
            return (h * 2.0, i + 1), None
        (h, _), _ = jax.lax.scan(body, (x, jnp.int32(0)), None, length=4)
        return h

    c = _compile(f, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    comps = _parse_computations(c.as_text())
    mult = _multipliers(comps)
    assert max(mult.values()) == 4  # while body found despite nested parens


def test_unsharded_matmul_flops_exact():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 64), jnp.float32))
    w = hlo_weighted_costs(c.as_text())
    assert w["flops"] == 2 * 128 * 256 * 64
    total, by_op = collective_bytes(c.as_text())
    assert total == 0
