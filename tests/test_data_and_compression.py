"""Data partitioning (hypothesis properties) + update compression."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.partition import dirichlet_partition, iid_partition, label_distribution
from repro.fl.compression import ErrorFeedback, compressed_bytes, topk_compress, topk_decompress


@settings(max_examples=10, deadline=None)
@given(n=st.integers(50, 500), c=st.integers(2, 10), k=st.integers(2, 20),
       alpha=st.sampled_from([0.1, 1.0, 100.0]))
def test_dirichlet_partition_covers_everything(n, c, k, alpha):
    rng = np.random.RandomState(0)
    labels = rng.randint(0, c, n)
    parts = dirichlet_partition(labels, k, alpha=alpha, seed=1)
    assert len(parts) == k
    all_idx = np.concatenate(parts)
    assert set(all_idx.tolist()) <= set(range(n))
    assert len(set(np.concatenate([p for p in parts]).tolist())) >= n * 0.95
    for p in parts:
        assert len(p) >= 2  # min_per_client floor


def test_skew_increases_as_alpha_decreases():
    rng = np.random.RandomState(0)
    labels = rng.randint(0, 10, 5000)

    def skew(alpha):
        parts = dirichlet_partition(labels, 20, alpha=alpha, seed=2)
        dist = label_distribution(labels, parts, 10)
        return float(np.mean(np.max(dist, axis=1)))  # mean top-class share

    assert skew(0.1) > skew(1.0) > skew(100.0)


def test_iid_partition_balanced():
    labels = np.arange(1000) % 7
    parts = iid_partition(labels, 10, seed=0)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1


def test_topk_roundtrip_and_ratio():
    rng = np.random.RandomState(0)
    tree = {"w": jnp.asarray(rng.randn(100, 100), jnp.float32)}
    sparse = topk_compress(tree, ratio=0.01)
    dense = topk_decompress(sparse, tree)
    # kept entries exact, bytes ~ 2% of dense
    w = np.asarray(tree["w"]).ravel()
    d = np.asarray(dense["w"]).ravel()
    nz = d != 0
    assert nz.sum() == 100  # 1% of 10000
    np.testing.assert_allclose(d[nz], w[nz])
    assert compressed_bytes(sparse) < 0.03 * w.nbytes


def test_error_feedback_beats_plain_topk():
    """EF corrects the compression bias over rounds: the accumulated
    transmitted signal tracks n*delta much closer than memoryless top-k."""
    rng = np.random.RandomState(0)
    delta = {"w": jnp.asarray(rng.randn(50), jnp.float32)}
    n = 60
    ef = ErrorFeedback(ratio=0.1)
    tot_ef = np.zeros(50, np.float32)
    tot_plain = np.zeros(50, np.float32)
    for _ in range(n):
        _, sent = ef.compress(delta)
        tot_ef += np.asarray(sent["w"])
        plain = topk_decompress(topk_compress(delta, 0.1), delta)
        tot_plain += np.asarray(plain["w"])
    target = n * np.asarray(delta["w"])
    err_ef = np.linalg.norm(tot_ef - target)
    err_plain = np.linalg.norm(tot_plain - target)
    assert err_ef < 0.5 * err_plain, (err_ef, err_plain)
    # most coordinates transmitted at least once under EF
    assert np.mean(tot_ef != 0) > 0.75
