"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis) in
interpret mode (CPU executes the kernel bodies in Python)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.block_perturb import diff_sqnorm, tree_diff_sqnorm
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ssm_scan import ssd_scan

RNG = np.random.RandomState(0)


def _rand(shape, dtype):
    x = RNG.randn(*shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([64, 128, 256]),
    heads=st.sampled_from([(2, 1), (4, 2), (4, 4)]),
    d=st.sampled_from([16, 32]),
    causal=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_sweep(B, S, heads, d, causal, dtype):
    Hq, Hkv = heads
    q = _rand((B, S, Hq, d), dtype)
    k = _rand((B, S, Hkv, d), dtype)
    v = _rand((B, S, Hkv, d), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=64, block_k=32,
                              interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shapes():
    q = _rand((1, 256, 2, 32), jnp.float32)
    k = _rand((1, 256, 2, 32), jnp.float32)
    v = _rand((1, 256, 2, 32), jnp.float32)
    base = ref.flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(32, 32), (64, 128), (256, 64), (128, 256)]:
        out = flash_attention_fwd(q, k, v, causal=True, block_q=bq, block_k=bk,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    B=st.sampled_from([1, 3]),
    S=st.sampled_from([128, 512]),
    heads=st.sampled_from([(2, 1), (4, 2)]),
    d=st.sampled_from([16, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    frac=st.sampled_from([0.25, 0.9, 1.0]),
)
def test_decode_attention_sweep(B, S, heads, d, dtype, frac):
    Hq, Hkv = heads
    q = _rand((B, Hq, d), dtype)
    k = _rand((B, S, Hkv, d), dtype)
    v = _rand((B, S, Hkv, d), dtype)
    length = jnp.asarray([max(1, int(S * frac))] * B, jnp.int32)
    out = decode_attention(q, k, v, length, block_k=64, interpret=True)
    expected = ref.decode_attention_ref(q, k, v, length)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([64, 256]),
    H=st.sampled_from([1, 3]),
    hd=st.sampled_from([8, 16]),
    N=st.sampled_from([4, 16]),
    chunk=st.sampled_from([32, 64]),
)
def test_ssd_scan_sweep(B, S, H, hd, N, chunk):
    x = _rand((B, S, H, hd), jnp.float32)
    dt = jnp.abs(_rand((B, S, H), jnp.float32)) * 0.3
    la = -jnp.abs(_rand((B, S, H), jnp.float32)) * 0.2
    Bm = _rand((B, S, N), jnp.float32)
    Cm = _rand((B, S, N), jnp.float32)
    y = ssd_scan(x, dt, la, Bm, Cm, chunk=chunk, interpret=True)
    expected = ref.ssd_scan_ref(x, dt, la, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expected),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# block perturbation reduction
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(n=st.integers(3, 100000),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_diff_sqnorm_sweep(n, dtype):
    a = _rand((n,), dtype)
    b = _rand((n,), dtype)
    got = float(diff_sqnorm(a, b, block=4096, interpret=True))
    want = float(ref.diff_sqnorm_ref(a, b))
    assert abs(got - want) <= 1e-4 * max(abs(want), 1.0)


def test_tree_diff_sqnorm():
    t1 = {"a": _rand((37, 5), jnp.float32), "b": {"c": _rand((11,), jnp.float32)}}
    t2 = jax.tree.map(lambda x: x + 0.5, t1)
    got = float(tree_diff_sqnorm(t1, t2, interpret=True))
    want = sum(float(ref.diff_sqnorm_ref(x, y)) for x, y in
               zip(jax.tree.leaves(t1), jax.tree.leaves(t2)))
    assert abs(got - want) < 1e-3


# ---------------------------------------------------------------------------
# ragged / adversarial differential fuzz (kernel wrappers vs refs)
# ---------------------------------------------------------------------------

pytestmark = pytest.mark.kernels


@settings(max_examples=8, deadline=None)
@given(
    S=st.sampled_from([7, 65, 100, 130, 255]),
    blocks=st.sampled_from([(32, 32), (64, 32), (32, 64)]),
    causal=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_ragged_seq(S, blocks, causal, dtype):
    """S not a multiple of the block shapes: the wrapper zero-pads to
    lcm(block_q, block_k) alignment and masks padded key columns in-kernel.
    A fully-padded kv block must be SKIPPED (not just masked) or the online
    softmax denominator is inflated by exp(0) rows — this sweep would catch
    that corruption on every non-causal draw."""
    bq, bk = blocks
    q = _rand((2, S, 2, 16), dtype)
    k = _rand((2, S, 2, 16), dtype)
    v = _rand((2, S, 2, 16), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=bq, block_k=bk,
                              interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal)
    assert out.shape == expected.shape
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_divisible_path_unchanged():
    """When S divides both blocks, the wrapper must take the exact
    pre-padding graph — same output as an explicitly padded call sliced
    back, and bitwise equal to itself across calls (no data-dependent
    branching)."""
    q = _rand((1, 128, 2, 16), jnp.float32)
    k = _rand((1, 128, 2, 16), jnp.float32)
    v = _rand((1, 128, 2, 16), jnp.float32)
    a = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=32,
                            interpret=True)
    b = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=32,
                            interpret=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=8, deadline=None)
@given(
    S=st.sampled_from([33, 100, 130]),
    block_k=st.sampled_from([32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_decode_attention_ragged_cache(S, block_k, dtype):
    """Cache lengths that are ragged relative to block_k, plus per-row
    lengths shorter than the padded cache."""
    B = 3
    q = _rand((B, 4, 16), dtype)
    k = _rand((B, S, 2, 16), dtype)
    v = _rand((B, S, 2, 16), dtype)
    length = jnp.asarray([S, max(1, S // 2), 1], jnp.int32)
    out = decode_attention(q, k, v, length, block_k=block_k, interpret=True)
    expected = ref.decode_attention_ref(q, k, v, length)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_zero_length_rows():
    """length == 0 (empty cache row): the kernel's gated body never runs
    and the row comes back all-zero — and the reference agrees (its softmax
    is zeroed where length == 0, not NaN from an all-masked row)."""
    B, S = 3, 64
    q = _rand((B, 2, 16), jnp.float32)
    k = _rand((B, S, 1, 16), jnp.float32)
    v = _rand((B, S, 1, 16), jnp.float32)
    length = jnp.asarray([0, S, 0], jnp.int32)
    out = np.asarray(decode_attention(q, k, v, length, block_k=32,
                                      interpret=True))
    expected = np.asarray(ref.decode_attention_ref(q, k, v, length))
    assert np.all(np.isfinite(expected))
    assert np.all(out[0] == 0.0) and np.all(out[2] == 0.0)
    np.testing.assert_allclose(out, expected, rtol=2e-5, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(n=st.sampled_from([5, 4097, 10001]),
       magnitude=st.sampled_from([1e-20, 1.0, 1e15]))
def test_diff_sqnorm_extreme_magnitudes(n, magnitude):
    """block_perturb reduction under denormal-adjacent and huge inputs:
    the f32 accumulator must track the reference within relative tol
    (both saturate to inf together past f32 range)."""
    a = _rand((n,), jnp.float32) * magnitude
    b = _rand((n,), jnp.float32) * magnitude
    got = float(diff_sqnorm(a, b, block=4096, interpret=True))
    want = float(ref.diff_sqnorm_ref(a, b))
    if np.isinf(want):
        assert np.isinf(got)
    else:
        assert abs(got - want) <= 1e-4 * max(abs(want), 1e-30)
