"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis) in
interpret mode (CPU executes the kernel bodies in Python)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.block_perturb import diff_sqnorm, tree_diff_sqnorm
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ssm_scan import ssd_scan

RNG = np.random.RandomState(0)


def _rand(shape, dtype):
    x = RNG.randn(*shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([64, 128, 256]),
    heads=st.sampled_from([(2, 1), (4, 2), (4, 4)]),
    d=st.sampled_from([16, 32]),
    causal=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_sweep(B, S, heads, d, causal, dtype):
    Hq, Hkv = heads
    q = _rand((B, S, Hq, d), dtype)
    k = _rand((B, S, Hkv, d), dtype)
    v = _rand((B, S, Hkv, d), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=64, block_k=32,
                              interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shapes():
    q = _rand((1, 256, 2, 32), jnp.float32)
    k = _rand((1, 256, 2, 32), jnp.float32)
    v = _rand((1, 256, 2, 32), jnp.float32)
    base = ref.flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(32, 32), (64, 128), (256, 64), (128, 256)]:
        out = flash_attention_fwd(q, k, v, causal=True, block_q=bq, block_k=bk,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    B=st.sampled_from([1, 3]),
    S=st.sampled_from([128, 512]),
    heads=st.sampled_from([(2, 1), (4, 2)]),
    d=st.sampled_from([16, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    frac=st.sampled_from([0.25, 0.9, 1.0]),
)
def test_decode_attention_sweep(B, S, heads, d, dtype, frac):
    Hq, Hkv = heads
    q = _rand((B, Hq, d), dtype)
    k = _rand((B, S, Hkv, d), dtype)
    v = _rand((B, S, Hkv, d), dtype)
    length = jnp.asarray([max(1, int(S * frac))] * B, jnp.int32)
    out = decode_attention(q, k, v, length, block_k=64, interpret=True)
    expected = ref.decode_attention_ref(q, k, v, length)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([64, 256]),
    H=st.sampled_from([1, 3]),
    hd=st.sampled_from([8, 16]),
    N=st.sampled_from([4, 16]),
    chunk=st.sampled_from([32, 64]),
)
def test_ssd_scan_sweep(B, S, H, hd, N, chunk):
    x = _rand((B, S, H, hd), jnp.float32)
    dt = jnp.abs(_rand((B, S, H), jnp.float32)) * 0.3
    la = -jnp.abs(_rand((B, S, H), jnp.float32)) * 0.2
    Bm = _rand((B, S, N), jnp.float32)
    Cm = _rand((B, S, N), jnp.float32)
    y = ssd_scan(x, dt, la, Bm, Cm, chunk=chunk, interpret=True)
    expected = ref.ssd_scan_ref(x, dt, la, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expected),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# block perturbation reduction
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(n=st.integers(3, 100000),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_diff_sqnorm_sweep(n, dtype):
    a = _rand((n,), dtype)
    b = _rand((n,), dtype)
    got = float(diff_sqnorm(a, b, block=4096, interpret=True))
    want = float(ref.diff_sqnorm_ref(a, b))
    assert abs(got - want) <= 1e-4 * max(abs(want), 1.0)


def test_tree_diff_sqnorm():
    t1 = {"a": _rand((37, 5), jnp.float32), "b": {"c": _rand((11,), jnp.float32)}}
    t2 = jax.tree.map(lambda x: x + 0.5, t1)
    got = float(tree_diff_sqnorm(t1, t2, interpret=True))
    want = sum(float(ref.diff_sqnorm_ref(x, y)) for x, y in
               zip(jax.tree.leaves(t1), jax.tree.leaves(t2)))
    assert abs(got - want) < 1e-3
