"""Pace controller: Eq. 2 telescoping correctness + freeze behaviour."""
import numpy as np
import pytest

from repro.core.pace import PaceController


def _feed(ctrl, params_seq):
    out = []
    for p in params_seq:
        out.append(ctrl.observe({"w": p}))
    return out


def test_perturbation_matches_eq2_directly():
    """P = ||sum_q U|| / sum_q ||U|| with U the per-round updates."""
    rng = np.random.RandomState(0)
    Q = 4
    ctrl = PaceController(window_q=Q, min_rounds=1)
    thetas = [rng.randn(50).astype(np.float32)]
    for _ in range(10):
        thetas.append(thetas[-1] + rng.randn(50).astype(np.float32) * 0.1)
    _feed(ctrl, thetas)
    # direct Eq. 2 at the last round
    updates = [thetas[i + 1] - thetas[i] for i in range(len(thetas) - 1)]
    last_q = updates[-Q:]
    num = np.linalg.norm(np.sum(last_q, axis=0))
    den = sum(np.linalg.norm(u) for u in last_q)
    expect = num / den
    got = ctrl._perturbations[-1]
    np.testing.assert_allclose(got, expect, rtol=1e-5)


def test_converged_sequence_freezes():
    rng = np.random.RandomState(1)
    ctrl = PaceController(window_q=3, smooth_h=3, mu=2, min_rounds=5,
                          slope_lambda=5e-2)
    theta = rng.randn(100).astype(np.float32)
    frozen_at = None
    for r in range(60):
        scale = 0.5 / (1 + r)  # decaying, oscillating updates -> converging
        theta = theta + scale * rng.randn(100).astype(np.float32)
        ctrl.observe({"w": theta})
        if ctrl.should_freeze():
            frozen_at = r
            break
    assert frozen_at is not None, ctrl.history


def test_diverging_sequence_does_not_freeze_early():
    rng = np.random.RandomState(2)
    ctrl = PaceController(window_q=3, smooth_h=3, mu=3, min_rounds=5,
                          slope_lambda=1e-4)
    theta = np.zeros(100, np.float32)
    for r in range(15):
        theta = theta + 1.0 + rng.randn(100).astype(np.float32) * 0.01
        ctrl.observe({"w": theta})
        # steady drift in one direction: perturbation stays ~1 with slope ~0
        # but the rounds guard + tight lambda keep it honest; the real guard
        # is that perturbation stays HIGH:
    assert ctrl._smoothed[-1] > 0.9  # consistent updates -> no convergence


def test_min_rounds_guard():
    ctrl = PaceController(min_rounds=10)
    for _ in range(3):
        ctrl.observe({"w": np.zeros(10, np.float32)})
    assert not ctrl.should_freeze()


def _seed_reference_series(params_seq, Q):
    """The seed implementation's algorithm, verbatim: a FIFO of Q+1 full
    snapshots, numerator = newest - oldest, denominator = scalar-norm FIFO.
    The refactored telescoped/flat-window controller must emit the identical
    perturbation series."""
    from collections import deque
    snaps, norms, perts = deque(), deque(), []
    for p in params_seq:
        p = np.asarray(p, np.float32)
        if snaps:
            norms.append(float(np.linalg.norm(
                (p - snaps[-1]).astype(np.float64))))
            if len(norms) > Q:
                norms.popleft()
        snaps.append(p)
        if len(snaps) > Q + 1:
            snaps.popleft()
        if len(snaps) < 2:
            continue
        num = float(np.linalg.norm((snaps[-1] - snaps[0]).astype(np.float64)))
        perts.append(num / (sum(norms) + 1e-12))
    return perts


def test_flat_window_series_identical_to_seed_algorithm():
    """Satellite check: the storage refactor (flat vectors instead of Q+1
    structured pytree snapshot copies) changes ZERO perturbation values."""
    rng = np.random.RandomState(3)
    for Q in (1, 3, 5):
        ctrl = PaceController(window_q=Q, min_rounds=1)
        thetas = [rng.randn(64).astype(np.float32)]
        for _ in range(25):
            thetas.append(thetas[-1]
                          + rng.randn(64).astype(np.float32) * 0.2)
        _feed(ctrl, thetas)
        ref = _seed_reference_series(thetas, Q)
        np.testing.assert_allclose(ctrl._perturbations, ref, rtol=1e-10)


def test_low_memory_window_tracks_exact_and_freezes():
    """The anchored (low_memory=True) window keeps 2 block copies instead of
    Q+1; its perturbation tracks the exact series on converging sequences
    and reaches the same freeze decision within a few rounds."""
    rng = np.random.RandomState(7)
    exact = PaceController(window_q=4, smooth_h=3, mu=2, min_rounds=5,
                           slope_lambda=5e-2)
    lowmem = PaceController(window_q=4, smooth_h=3, mu=2, min_rounds=5,
                            slope_lambda=5e-2, low_memory=True)
    theta = rng.randn(100).astype(np.float32)
    froze_exact = froze_low = None
    for r in range(60):
        theta = theta + (0.5 / (1 + r)) * rng.randn(100).astype(np.float32)
        exact.observe({"w": theta})
        lowmem.observe({"w": theta})
        if froze_exact is None and exact.should_freeze():
            froze_exact = r
        if froze_low is None and lowmem.should_freeze():
            froze_low = r
        if froze_exact is not None and froze_low is not None:
            break
    assert froze_exact is not None and froze_low is not None
    assert abs(froze_exact - froze_low) <= 5
    # low-memory state really is O(1) block copies
    assert len(lowmem._window) == 0
    assert lowmem._anchor is not None and lowmem._prev is not None
    assert len(exact._window) == 5  # Q + 1


def test_schedules():
    from repro.core.pace import front_loaded_schedule, naive_equal_schedule

    assert sum(front_loaded_schedule(100, 4)) == 100
    assert len(naive_equal_schedule(100, 4)) == 4
