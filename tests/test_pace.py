"""Pace controller: Eq. 2 telescoping correctness + freeze behaviour."""
import numpy as np
import pytest

from repro.core.pace import PaceController


def _feed(ctrl, params_seq):
    out = []
    for p in params_seq:
        out.append(ctrl.observe({"w": p}))
    return out


def test_perturbation_matches_eq2_directly():
    """P = ||sum_q U|| / sum_q ||U|| with U the per-round updates."""
    rng = np.random.RandomState(0)
    Q = 4
    ctrl = PaceController(window_q=Q, min_rounds=1)
    thetas = [rng.randn(50).astype(np.float32)]
    for _ in range(10):
        thetas.append(thetas[-1] + rng.randn(50).astype(np.float32) * 0.1)
    _feed(ctrl, thetas)
    # direct Eq. 2 at the last round
    updates = [thetas[i + 1] - thetas[i] for i in range(len(thetas) - 1)]
    last_q = updates[-Q:]
    num = np.linalg.norm(np.sum(last_q, axis=0))
    den = sum(np.linalg.norm(u) for u in last_q)
    expect = num / den
    got = ctrl._perturbations[-1]
    np.testing.assert_allclose(got, expect, rtol=1e-5)


def test_converged_sequence_freezes():
    rng = np.random.RandomState(1)
    ctrl = PaceController(window_q=3, smooth_h=3, mu=2, min_rounds=5,
                          slope_lambda=5e-2)
    theta = rng.randn(100).astype(np.float32)
    frozen_at = None
    for r in range(60):
        scale = 0.5 / (1 + r)  # decaying, oscillating updates -> converging
        theta = theta + scale * rng.randn(100).astype(np.float32)
        ctrl.observe({"w": theta})
        if ctrl.should_freeze():
            frozen_at = r
            break
    assert frozen_at is not None, ctrl.history


def test_diverging_sequence_does_not_freeze_early():
    rng = np.random.RandomState(2)
    ctrl = PaceController(window_q=3, smooth_h=3, mu=3, min_rounds=5,
                          slope_lambda=1e-4)
    theta = np.zeros(100, np.float32)
    for r in range(15):
        theta = theta + 1.0 + rng.randn(100).astype(np.float32) * 0.01
        ctrl.observe({"w": theta})
        # steady drift in one direction: perturbation stays ~1 with slope ~0
        # but the rounds guard + tight lambda keep it honest; the real guard
        # is that perturbation stays HIGH:
    assert ctrl._smoothed[-1] > 0.9  # consistent updates -> no convergence


def test_min_rounds_guard():
    ctrl = PaceController(min_rounds=10)
    for _ in range(3):
        ctrl.observe({"w": np.zeros(10, np.float32)})
    assert not ctrl.should_freeze()


def test_schedules():
    from repro.core.pace import front_loaded_schedule, naive_equal_schedule

    assert sum(front_loaded_schedule(100, 4)) == 100
    assert len(naive_equal_schedule(100, 4)) == 4
