"""End-to-end behaviour tests for the SmartFreeze system (paper pipeline)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.freezing_cnn import (cnn_stage_forward, init_cnn_stage_active,
                                     make_cnn_stage_step, merge_cnn_params)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticVision
from repro.fl.client import make_client_fleet
from repro.fl.server import FedAvgServer, SmartFreezeServer
from repro.models.cnn import CNN, CNNConfig
from repro.optim import sgd

TINY = CNNConfig("tiny_resnet", "resnet", stage_sizes=(1, 1), stage_channels=(8, 16))


@pytest.fixture(scope="module")
def fl_world():
    sv = SyntheticVision(num_classes=4, image_size=16, seed=0)
    train = sv.sample(800, seed=1)
    test = sv.sample(200, seed=2)
    parts = dirichlet_partition(train["y"], 10, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    return train, test, clients


def test_smartfreeze_end_to_end(fl_world):
    """Full pipeline: similarity -> RL-CD -> selection -> stage rounds ->
    pace freeze -> model growth. Accuracy must beat chance."""
    _, test, clients = fl_world
    model = CNN(dataclasses.replace(TINY, num_classes=4))
    params, state = model.init(jax.random.PRNGKey(0))

    def eval_fn(p, s, stage):
        logits, _ = model.apply(p, s, jnp.asarray(test["x"]), train=False)
        return float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())

    srv = SmartFreezeServer(model, clients, clients_per_round=4,
                            local_epochs=1, batch_size=32, rounds_per_stage=5,
                            pace_kwargs=dict(min_rounds=3, mu=2,
                                             slope_lambda=5e-2))
    out = srv.run(params, state, eval_fn=eval_fn, eval_every=2)
    assert out["rounds"] <= 10
    stages_seen = {r.stage for r in out["history"]}
    assert stages_seen == {0, 1}  # both blocks trained (model growth happened)
    final_acc = eval_fn(out["params"], out["state"], 1)
    assert final_acc > 0.3, final_acc  # 4 classes, chance = 0.25


def test_cnn_stage_frozen_prefix_is_fixed(fl_world):
    train, _, _ = fl_world
    model = CNN(dataclasses.replace(TINY, num_classes=4))
    params, state = model.init(jax.random.PRNGKey(0))
    frozen, active = init_cnn_stage_active(model, params, 1,
                                           jax.random.PRNGKey(1))
    step = make_cnn_stage_step(model, 1, sgd(0.1))
    opt_state = sgd(0.1).init(active)
    batch = {"x": jnp.asarray(train["x"][:16]), "y": jnp.asarray(train["y"][:16])}
    a2, s2, opt_state, loss = step(active, frozen, state, opt_state, batch)
    # stage-1 params moved; stage-0 lives only in the frozen tree
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         a2["stages"], active["stages"])
    assert max(jax.tree.leaves(moved)) > 0
    assert "stage0" in frozen["stages"] and "stage0" not in active["stages"]


def test_vanilla_fedavg_baseline_runs(fl_world):
    train, test, clients = fl_world
    model = CNN(dataclasses.replace(TINY, num_classes=4))
    params, state = model.init(jax.random.PRNGKey(0))
    srv = FedAvgServer(model, clients, clients_per_round=4, batch_size=32)
    out = srv.run(params, state, rounds=3)
    assert len(out["history"]) == 3
    assert np.isfinite(out["history"][-1].loss)


def test_straggler_deadline_reduces_cohort(fl_world):
    _, _, clients = fl_world
    model = CNN(dataclasses.replace(TINY, num_classes=4))
    params, state = model.init(jax.random.PRNGKey(0))
    srv = SmartFreezeServer(model, clients, clients_per_round=8,
                            rounds_per_stage=1, deadline_factor=1.0,
                            pace_kwargs=dict(min_rounds=99))
    out = srv.run(params, state, total_rounds=2)
    # with a deadline at the median time, some rounds must drop stragglers
    sizes = [len(r.selected) for r in out["history"]]
    assert min(sizes) < 8
