"""Decode path == full forward: run t decode steps from an empty cache and
compare the last-token logits to the full-sequence forward (fp32 params)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.transformer import build

CASES = ["llama3-8b", "minicpm3-4b", "xlstm-350m", "zamba2-7b", "grok-1-314b"]


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_forward(arch):
    cfg = configs.get(arch).reduced(param_dtype="float32",
                                    compute_dtype="float32",
                                    capacity_factor=8.0)  # no MoE token drops
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    full_logits, _ = model.forward(params, {"tokens": toks})

    cache = model.init_cache(batch=B, max_seq=T)
    step = jax.jit(model.decode_step)
    for t in range(T):
        dec_logits, cache = step(params, {"tokens": toks[:, t:t + 1]}, cache,
                                 jnp.int32(t))
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)
