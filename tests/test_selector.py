"""Participant selector: Louvain (vs networkx), RL-CD, Eq. 11-14 selection."""
import numpy as np
import pytest

from repro.core.selector import ClientInfo, ParticipantSelector, rlcd_communities
from repro.core.selector.bandit import UtilBandit
from repro.core.selector.louvain import louvain, modularity
from repro.core.selector.selection import InfeasibleStageError
from repro.core.selector.similarity import similarity_matrix


def _clustered_sim(n_groups=3, per=4, noise=0.05, seed=0):
    rng = np.random.RandomState(seed)
    vecs = {}
    for g in range(n_groups):
        proto = np.zeros(48)
        proto[g * 16:(g + 1) * 16] = 1.0
        for i in range(per):
            vecs[g * per + i] = proto + rng.randn(48) * noise
    return similarity_matrix(vecs), n_groups, per


def test_louvain_recovers_planted_groups():
    W, n_groups, per = _clustered_sim()
    comms = louvain(np.maximum(W, 0))
    assert len(comms) == n_groups
    for c in comms:
        assert len(c) == per
        assert {i // per for i in c} == {c[0] // per}


def test_louvain_matches_networkx_modularity():
    import networkx as nx

    W, _, _ = _clustered_sim(noise=0.15, seed=3)
    Wp = np.maximum(W, 0)
    np.fill_diagonal(Wp, 0)
    ours = louvain(Wp)
    G = nx.from_numpy_array(Wp)
    theirs = [sorted(c) for c in nx.community.louvain_communities(G, seed=0)]
    q_ours = modularity(Wp, ours)
    q_theirs = modularity(Wp, [list(c) for c in theirs])
    assert q_ours >= q_theirs - 1e-3  # at least as good a partition


def test_rlcd_splits_noisy_subgroup():
    """Paper Fig. 6: strong {0,1} and weak {8,9} label-0 clients separate."""
    rng = np.random.RandomState(0)
    protos = {0: 0, 1: 0, 8: 0, 9: 0, 2: 1, 3: 1, 4: 1, 5: 2, 6: 2, 7: 2}
    vecs = {}
    for cid, g in protos.items():
        v = np.zeros(64)
        v[g * 16:(g + 1) * 16] = 1.0
        if cid in (8, 9):
            v = v * 0.3 + rng.randn(64) * 0.35
        else:
            v = v + rng.randn(64) * 0.05
        vecs[cid] = v
    comms = rlcd_communities(similarity_matrix(vecs))
    # 8 and 9 must not share a community with BOTH 0 and 1 anymore
    for c in comms:
        if 0 in c and 1 in c:
            assert not (8 in c and 9 in c)


def test_selection_respects_memory_and_phi():
    sel = ParticipantSelector(phi=3)
    clients = {i: ClientInfo(i, memory_bytes=i * 2**30, capability=1e9,
                             num_samples=10, loss_sum=1.0) for i in range(10)}
    picked = sel.select(clients, 4, mem_required=5 * 2**30,
                        stage_time_fn=lambda c: 1.0)
    assert all(clients[c].memory_bytes >= 5 * 2**30 for c in picked)
    with pytest.raises(InfeasibleStageError):
        sel.select(clients, 4, mem_required=8.5 * 2**30,
                   stage_time_fn=lambda c: 1.0)


def test_selection_covers_communities():
    W, n_groups, per = _clustered_sim()
    sel = ParticipantSelector(phi=1, epsilon=0.0)
    sel.fit_communities(W)
    clients = {i: ClientInfo(i, memory_bytes=2**33, capability=1e9,
                             num_samples=10, loss_sum=float(i)) for i in range(12)}
    picked = sel.select(clients, n_groups, mem_required=0,
                        stage_time_fn=lambda c: 0.0)
    assert len({p // per for p in picked}) == n_groups  # one per community


def test_bandit_exploits_and_explores():
    b = UtilBandit(epsilon=0.5, seed=0)
    for cid in range(4):
        b.update(cid, float(cid))
    b.next_round()
    picked = b.pick(list(range(8)), 4)  # 4..7 never seen
    assert 3 in picked  # best known util exploited
    assert any(p >= 4 for p in picked)  # unseen explored


def test_bandit_seed_streams_are_decorrelated():
    """Regression: the old ``seed + round`` RNG made (seed=0, round=1) and
    (seed=1, round=0) share an exploration stream — two bandits with
    different seeds walked the same schedules one round apart. The mixed
    stream must diverge across seeds and stay reproducible per seed."""

    def explore_trace(seed, rounds=6):
        b = UtilBandit(epsilon=1.0, seed=seed)   # pure exploration
        trace = []
        for _ in range(rounds):
            for cid in range(12):
                b.update(cid, 0.0)               # equal utils, equal staleness
            b.next_round()
            trace.append(tuple(b.pick(list(range(12)), 4)))
        return trace

    assert explore_trace(0) == explore_trace(0)
    assert explore_trace(0) != explore_trace(1)
    # the old failure mode: seed 1's trace == seed 0's trace shifted a round
    assert explore_trace(0)[1:] != explore_trace(1)[:-1]


def test_selector_threads_seed_into_bandit():
    sel_a = ParticipantSelector(seed=17)
    assert sel_a._bandit.seed == 17
