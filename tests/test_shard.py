"""Sharded cohort execution (ISSUE 5): client-axis shard_map invariants.

Two layers:

  * in-process tests — mesh ``None`` vs a size-1 client mesh must be
    BIT-identical (the sharded path only engages at axis size > 1), and the
    sharding helpers must be identity/replicated fallbacks in degenerate
    configurations;
  * a subprocess driver (``tests/_shard_driver.py``) under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — an 8-way
    sharded round/server must match the single-device run allclose (f32)
    on params, BN state, losses, uplink bytes, and selection picks, with
    cohort-padding, tiered-cache, compressed-uplink, and
    population-divisibility edge cases. The forced-host-device flag must
    be set before jax initializes, hence the subprocess.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import freezing_cnn as fz
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticVision
from repro.fl.client import make_client_fleet
from repro.fl.engine import RoundEngine
from repro.launch.mesh import make_client_mesh
from repro.models.cnn import CNN, CNNConfig
from repro.optim import sgd

TINY = CNNConfig("tiny_resnet", "resnet", stage_sizes=(1, 1),
                 stage_channels=(8, 16), num_classes=4)


# ---------------------------------------------------------------------------
# in-process: degenerate meshes
# ---------------------------------------------------------------------------


def _world():
    sv = SyntheticVision(num_classes=4, image_size=16, seed=0)
    train = sv.sample(400, seed=1)
    parts = dirichlet_partition(train["y"], 5, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    model = CNN(TINY)
    params, state = model.init(jax.random.PRNGKey(0))
    return {c.client_id: c for c in clients}, model, params, state


def test_mesh_size_one_is_bit_identical():
    """A 1-device client mesh must reproduce the no-mesh trajectory
    bit-for-bit (the sharded path only engages at axis size > 1)."""
    by_id, model, params, state = _world()
    frozen, active = fz.init_cnn_stage_active(model, params, 0,
                                              jax.random.PRNGKey(1))
    sel = sorted(by_id)

    def run(mesh):
        eng = RoundEngine(loss_fn=fz.cnn_stage_loss_fn(model, 0),
                          optimizer=sgd(0.05), frozen=frozen, batch_size=32,
                          local_epochs=1, mesh=mesh)
        return eng.run_round(by_id, sel, active, state, 7)

    a0, s0, l0 = run(None)
    a1, s1, l1 = run(make_client_mesh(1))
    for x, y in zip(jax.tree.leaves((a0, s0)), jax.tree.leaves((a1, s1))):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert l0 == l1


def test_client_helpers_degenerate():
    from repro.dist.sharding import (client_axis_size, client_spec,
                                     shard_client_arrays)
    assert client_axis_size(None) == 1
    assert client_axis_size(make_client_mesh(1)) == 1
    # no active client axis: identity (no device_put, no copies)
    x = jnp.arange(6.0)
    assert shard_client_arrays(None, x) is x
    assert shard_client_arrays(make_client_mesh(1), x) is x
    assert tuple(client_spec(3)) == ("clients", None, None)


def test_population_shard_single_device_equal():
    """shard() on a 1-device mesh keeps kernels byte-equal (and drops the
    stage-time memo so it recomputes on the new placement)."""
    from repro.core.selector import ClientInfo, ClientPopulation
    from repro.core.selector.vectorized import assign_cache_tiers
    rng = np.random.RandomState(1)
    infos = {i: ClientInfo(i, float(rng.choice([1, 2, 4])) * 2**30, 1e9,
                           int(rng.randint(32, 256)), float(rng.rand()))
             for i in range(12)}
    pop = ClientPopulation.from_infos(infos)
    pop_s = pop.shard(make_client_mesh(1))
    rates = [4e3, 2e3, 1e3]
    assert np.array_equal(assign_cache_tiers(pop, 1e8, rates),
                          assign_cache_tiers(pop_s, 1e8, rates))
    assert np.array_equal(np.asarray(pop.stage_time()),
                          np.asarray(pop_s.stage_time()))


# ---------------------------------------------------------------------------
# subprocess: 8 forced host devices
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def shard_report():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable,
         os.path.join(os.path.dirname(__file__), "_shard_driver.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON:")]
    assert line, proc.stdout[-2000:]
    report = json.loads(line[-1][len("JSON:"):])
    assert report["n_devices"] == 8, report
    return report


@pytest.mark.slow
def test_sharded_round_matches_single_device(shard_report):
    assert shard_report["round_params_allclose"]
    assert shard_report["round_state_allclose"]
    assert shard_report["round_losses_allclose"]
    assert shard_report["round_uplink_equal"]


@pytest.mark.slow
def test_sharded_screening(shard_report):
    """ISSUE 7: defenses armed + zero faults is BIT-identical on the mesh;
    an injected nan update is screened with a finite aggregate matching the
    single-device defended round."""
    assert shard_report["screened_zero_fault_bitwise"]
    assert shard_report["screened_fault_finite"]
    assert shard_report["screened_fault_matches_single"]
    assert shard_report["screened_fault_flagged"]


@pytest.mark.slow
def test_cohort_smaller_than_mesh_padding(shard_report):
    assert shard_report["pad_params_allclose"]
    assert shard_report["pad_losses_allclose"]


@pytest.mark.slow
def test_tiered_cache_sharded(shard_report):
    assert shard_report["tiered_cache_allclose"]


@pytest.mark.slow
def test_mixed_tier_groups_sharded(shard_report):
    assert shard_report["mixed_groups_allclose"]


@pytest.mark.slow
def test_compressed_sharded(shard_report):
    assert shard_report["compressed_allclose"]
    assert shard_report["compressed_uplink_equal"]


@pytest.mark.slow
def test_server_sharded_trajectory(shard_report):
    assert shard_report["server_picks_equal"]
    assert shard_report["server_uplink_equal"]
    assert shard_report["server_losses_allclose"]
    assert shard_report["server_params_allclose"]
    assert shard_report["server_vtime_equal"]


@pytest.mark.slow
def test_population_sharded_kernels(shard_report):
    assert shard_report["population_picks_equal"]
    assert shard_report["admission_equal"]


@pytest.mark.slow
def test_population_nondivisible_fallback(shard_report):
    assert shard_report["nondiv_replicated"]
    assert shard_report["nondiv_admission_equal"]
