"""In-graph compressed uplink aggregation (PR 2): lax.top_k path vs the
host reference, ratio=1.0 == dense Eq. 1 (property), error-feedback
convergence, fused == sequential under compression."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.fl.compression import (deterministic_topk_indices,
                                  ingraph_compress_leaf,
                                  ingraph_sparse_aggregate, ingraph_topk,
                                  topk_compress, topk_keep)


def _engine_fixture(num_clients=4, samples=160, classes=4, image=8, seed=0):
    from repro.data.partition import iid_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.engine import RoundEngine
    from repro.models.cnn import CNN, CNNConfig
    from repro.optim import sgd

    sv = SyntheticVision(num_classes=classes, image_size=image)
    train = sv.sample(samples, seed=1)
    parts = iid_partition(train["y"], num_clients, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=seed)
    by_id = {c.client_id: c for c in clients}
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1,), stage_channels=(8,),
                    num_classes=classes)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(seed))

    def full_loss(p, frozen_unused, st, batch):
        return model.loss(p, st, batch, train=True)

    def make(ratio, fused=True):
        return RoundEngine(loss_fn=full_loss, optimizer=sgd(0.05),
                           batch_size=16, local_epochs=1, fused=fused,
                           compress_ratio=ratio)

    return by_id, sorted(by_id), params, state, make


def _leaves_allclose(a, b, rtol=2e-4, atol=2e-4):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# primitive-level: in-graph selection mirrors the host payload exactly
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(n=st.integers(8, 300), frac_ties=st.sampled_from([0.0, 0.3, 0.9]),
       seed=st.integers(0, 10_000))
def test_ingraph_topk_matches_host_selection(n, frac_ties, seed):
    """Same k entries, same (ascending) index order — including under
    magnitude ties, where argpartition used to be platform-dependent."""
    rng = np.random.RandomState(seed)
    flat = rng.randn(n).astype(np.float32)
    ties = rng.rand(n) < frac_ties
    flat[ties] = np.sign(flat[ties]) * 1.0       # plant exact-|value| ties
    k = max(1, n // 7)
    idx_host = deterministic_topk_indices(flat, k)
    idx_dev, vals_dev = ingraph_topk(jnp.asarray(flat), k)
    np.testing.assert_array_equal(idx_host, np.asarray(idx_dev))
    np.testing.assert_array_equal(flat[idx_host], np.asarray(vals_dev))
    assert (np.diff(np.asarray(idx_dev)) > 0).all()   # ascending payload


def test_topk_compress_payload_is_sorted_and_deterministic():
    flat = np.zeros(64, np.float32)
    flat[::2] = 0.5                                    # 32-way tie
    sparse = topk_compress({"w": jnp.asarray(flat)}, ratio=0.25)
    idx, vals, shape = sparse[0]
    assert (np.diff(idx) > 0).all()
    # ties resolved toward the lowest indices: the first 16 even slots
    np.testing.assert_array_equal(idx, np.arange(32, dtype=np.int32)[::2][:16])
    np.testing.assert_array_equal(vals, np.full(16, 0.5, np.float32))


def test_ingraph_sparse_aggregate_is_weighted_scatter_add():
    idx = jnp.asarray([[0, 2, 5], [2, 3, 5]], jnp.int32)
    vals = jnp.asarray([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]], jnp.float32)
    w = jnp.asarray([0.25, 0.75], jnp.float32)
    out = np.asarray(ingraph_sparse_aggregate(idx, vals, w, 8))
    expect = np.zeros(8, np.float32)
    expect[[0, 2, 5]] += 0.25 * np.asarray([1.0, 2.0, 3.0])
    expect[[2, 3, 5]] += 0.75 * np.asarray([4.0, 5.0, 6.0])
    np.testing.assert_allclose(out, expect, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(length=st.integers(16, 400), k_clients=st.integers(1, 5),
       seed=st.integers(0, 9999))
def test_compress_leaf_ratio1_is_exact_eq1(length, k_clients, seed):
    """Property: at ratio=1.0 the sparse path IS dense Eq. 1 aggregation."""
    rng = np.random.RandomState(seed)
    start = rng.randn(length).astype(np.float32)
    end = rng.randn(k_clients, length).astype(np.float32)
    res = jnp.zeros((k_clients, length), jnp.float32)
    w = rng.rand(k_clients).astype(np.float32) + 0.1
    w /= w.sum()
    agg, new_r, _, _ = ingraph_compress_leaf(
        jnp.asarray(start), jnp.asarray(end), res, jnp.asarray(w), 1.0)
    expect = start + (w[:, None] * (end - start[None])).sum(0)
    np.testing.assert_allclose(np.asarray(agg), expect, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_r), 0.0, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(ratio=st.sampled_from([0.05, 0.1, 0.3]), seed=st.integers(0, 999))
def test_error_feedback_residuals_converge(ratio, seed):
    """Property: over K rounds of compressing the SAME delta, the cumulative
    transmitted aggregate approaches K * delta (error feedback re-sends what
    top-k dropped), far closer than memoryless top-k."""
    rng = np.random.RandomState(seed)
    length, rounds = 120, 40
    delta = rng.randn(length).astype(np.float32)
    start = jnp.zeros(length, jnp.float32)
    res = jnp.zeros((1, length), jnp.float32)
    w = jnp.ones(1, jnp.float32)
    sent_ef = np.zeros(length, np.float64)
    sent_plain = np.zeros(length, np.float64)
    k = topk_keep(length, ratio)
    for _ in range(rounds):
        agg, res, _, _ = ingraph_compress_leaf(
            start, jnp.asarray(delta)[None], res, w, ratio)
        sent_ef += np.asarray(agg)
        i, v = ingraph_topk(jnp.asarray(delta), k)
        plain = np.zeros(length, np.float32)
        plain[np.asarray(i)] = np.asarray(v)
        sent_plain += plain
    target = rounds * delta.astype(np.float64)
    err_ef = np.linalg.norm(sent_ef - target)
    err_plain = np.linalg.norm(sent_plain - target)
    # EF's lag is a bounded backlog; memoryless top-k's error grows with
    # the round count (same 0.5 margin as the host-path EF test)
    assert err_ef < 0.5 * err_plain, (err_ef, err_plain)
    # the carried residual stays bounded (no drift)
    assert float(jnp.abs(res).max()) < np.abs(delta).max() * length


# ---------------------------------------------------------------------------
# engine-level: the fused compressed round
# ---------------------------------------------------------------------------


def test_fused_compressed_ratio1_matches_dense_round():
    by_id, sel, params, state, make = _engine_fixture()
    p_d, s_d, l_d = make(None).run_round(by_id, sel, params, state, 0)
    p_c, s_c, l_c = make(1.0).run_round(by_id, sel, params, state, 0)
    _leaves_allclose(p_d, p_c)
    _leaves_allclose(s_d, s_c)
    assert l_d.keys() == l_c.keys()
    for cid in l_d:
        assert abs(l_d[cid] - l_c[cid]) < 1e-4


def test_fused_compressed_equals_sequential_compressed():
    by_id, sel, params, state, make = _engine_fixture()
    ef, es = make(0.2, fused=True), make(0.2, fused=False)
    pf, sf = params, state
    ps, ss = params, state
    for r in range(3):
        pf, sf, _ = ef.run_round(by_id, sel, pf, sf, r)
        ps, ss, _ = es.run_round(by_id, sel, ps, ss, r)
    _leaves_allclose(pf, ps)
    _leaves_allclose(sf, ss)
    # identical error-feedback state too
    for cid in sel:
        for a, b in zip(ef.client_residuals(cid), es.client_residuals(cid)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)


def test_compressed_rounds_track_dense_training():
    """K rounds at ratio=0.1 with error feedback stay close to the dense
    trajectory (the compressed sum converges to the dense sum), while
    memoryless low-ratio rounds would not move most coordinates at all."""
    by_id, sel, params, state, make = _engine_fixture()
    e_d, e_c = make(None), make(0.1)
    pd, sd = params, state
    pc, sc = params, state
    for r in range(8):
        pd, sd, _ = e_d.run_round(by_id, sel, pd, sd, r)
        pc, sc, _ = e_c.run_round(by_id, sel, pc, sc, r)
    num = den = 0.0
    for a, b, p0 in zip(jax.tree.leaves(pd), jax.tree.leaves(pc),
                        jax.tree.leaves(params)):
        num += float(jnp.sum((a.astype(jnp.float32)
                              - b.astype(jnp.float32)) ** 2))
        den += float(jnp.sum((a.astype(jnp.float32)
                              - p0.astype(jnp.float32)) ** 2))
    assert den > 0
    assert (num / den) ** 0.5 < 0.5    # within 50% of the dense move
    # every client carries nonzero pent-up residual
    norms = e_c.residual_norms()
    assert set(norms) == set(sel)
    assert all(v > 0 for v in norms.values())


def test_uplink_bytes_accounting():
    by_id, sel, params, state, make = _engine_fixture()
    e_d, e_c = make(None), make(0.1)
    e_d.run_round(by_id, sel, params, state, 0)
    e_c.run_round(by_id, sel, params, state, 0)
    dense = sum(l.size * 4 for l in jax.tree.leaves(params)) * len(sel)
    assert e_d.last_uplink_bytes == dense
    assert 0 < e_c.last_uplink_bytes < 0.3 * dense


def test_server_compressed_run_and_history():
    """SmartFreezeServer with compress_ratio: trains, and logs shrunken
    uplink payloads per round."""
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.server import SmartFreezeServer
    from repro.models.cnn import CNN, CNNConfig

    sv = SyntheticVision(num_classes=4, image_size=8)
    train = sv.sample(256, seed=1)
    parts = dirichlet_partition(train["y"], 8, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1,), stage_channels=(8,),
                    num_classes=4)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))

    def run(ratio):
        srv = SmartFreezeServer(model, clients, clients_per_round=4,
                                batch_size=16, rounds_per_stage=2,
                                compress_ratio=ratio, seed=0,
                                pace_kwargs=dict(min_rounds=999))
        return srv.run(params, state, total_rounds=2)

    out_c, out_d = run(0.1), run(None)
    bytes_c = [r.uplink_bytes for r in out_c["history"]]
    bytes_d = [r.uplink_bytes for r in out_d["history"]]
    assert all(b is not None and 0 < b < 0.3 * d
               for b, d in zip(bytes_c, bytes_d))
