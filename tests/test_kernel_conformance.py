"""Differential conformance harness for the Pallas hot-path kernels.

Every Pallas kernel in ``kernels/`` ships with a pure-``lax`` reference in
``kernels/ref.py``; these tests are the contract between them. The two
roofline-ordered hot paths added for the tiered/compressed rounds get the
deepest coverage:

  * ``dequant_matmul`` — fused int8-dequant -> GEMM with per-(sample,
    channel) scales applied in-register (kernels/dequant_matmul.py).
  * ``sparse_cohort_add`` — one-kernel Eq. 1 fold of K clients' top-k
    (idx, vals) uplink rows (kernels/sparse_agg.py).

Structure: hypothesis-driven shape/dtype sweeps (ragged tails, non-divisible
block tilings), adversarial values (denormals, all-zero quantization groups,
near-overflow magnitudes), ``custom_vjp`` gradient checks against
``jax.grad`` of the reference, and end-to-end ``use_pallas=True`` federated
rounds allclose to the XLA default — up to a 2-stage SmartFreeze trajectory.

Tolerance convention: the Pallas GEMM accumulates split-K tiles in grid
order while the XLA dot uses a single fused reduction, so f32 results can
disagree by accumulation-order noise that is *relative to the magnitude of
the summands*, not the (possibly cancelled-to-small) output. ``_close``
therefore scales atol by ``max(1, |want|_inf)``. Gradient probes are LINEAR
(``sum(probe * out)``) for the same reason — a nonlinear probe like ``sin``
at large outputs amplifies forward noise into the cotangents.

All tests run the kernels in interpret mode on CPU (``ops`` defaults
``interpret=True`` off-TPU), so CI executes the real kernel bodies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fl import quant
from repro.fl.compression import (ingraph_compress_leaf,
                                  ingraph_sparse_aggregate)
from repro.fl.engine import make_fused_round
from repro.kernels import ops, ref, sparse_agg
from repro.kernels.dequant_matmul import normalize_scale
from repro.optim import sgd

pytestmark = pytest.mark.kernels

jax.config.update("jax_platform_name", "cpu")


def _close(got, want, tol=1e-5):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    assert got.shape == want.shape
    assert np.all(np.isfinite(got) == np.isfinite(want))
    atol = tol * max(1.0, float(np.max(np.abs(want))) if want.size else 1.0)
    np.testing.assert_allclose(got, want, rtol=tol, atol=atol)


def _rand(seed, shape, scale=1.0):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# dequant_matmul: forward conformance
# ---------------------------------------------------------------------------


def test_dqmm_int8_row_scales_matches_ref():
    """The production configuration: int8 cache rows + [N, 1] quantizer
    scales, exactly as ``quant.quantize_int8`` emits for 2-D features."""
    x = _rand(0, (32, 48), 3.0)
    q, scale = quant.quantize_int8(x)
    w = _rand(1, (48, 16))
    got = ops.dequant_matmul(q, scale, w)
    want = ref.dequant_matmul_ref(q, scale, w)
    _close(got, want)


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
       block=st.sampled_from([8, 16, 32]))
def test_dqmm_shape_sweep(m, k, n, block):
    """Hypothesis sweep over ragged (M, K, N) x block tilings: tails that
    do not divide the block shape are zero-padded by the wrapper and must
    never leak into the valid region."""
    x = _rand(m * 1000 + k * 10 + n, (m, k), 2.0)
    q, scale = quant.quantize_int8(x)
    w = _rand(7, (k, n))
    got = ops.dequant_matmul(q, scale, w,
                             block_m=block, block_n=block, block_k=block)
    _close(got, ref.dequant_matmul_ref(q, scale, w))


@pytest.mark.parametrize("kind", ["row", "col", "full", "scalar"])
def test_dqmm_scale_kinds(kind):
    """All four broadcast layouts the wrapper normalizes: per-row [M, 1],
    per-column [1, K] / [K], dense [M, K], and a 0-d scalar."""
    M, K, N = 19, 33, 11
    q = _rand(3, (M, K), 4.0).astype(jnp.int8)
    shapes = {"row": (M, 1), "col": (K,), "full": (M, K), "scalar": ()}
    scale = jnp.abs(_rand(4, shapes[kind])) + 0.01
    w = _rand(5, (K, N))
    got = ops.dequant_matmul(q, scale, w, block_m=16, block_n=16, block_k=16)
    _close(got, ref.dequant_matmul_ref(q, scale, w))


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_dqmm_float_inputs(dtype, tol):
    """Float (non-quantized) q values: the kernel upcasts to f32 before the
    scale multiply, so bf16 inputs lose only their own storage precision."""
    q = _rand(11, (24, 40)).astype(dtype)
    scale = jnp.abs(_rand(12, (24, 1))) + 0.1
    w = _rand(13, (40, 8))
    got = ops.dequant_matmul(q, scale, w, block_m=16, block_n=16, block_k=16)
    _close(got, ref.dequant_matmul_ref(q.astype(jnp.float32), scale, w), tol)


@pytest.mark.parametrize("shape", [(1, 1, 1), (5, 3, 2), (257, 129, 65)])
def test_dqmm_ragged_tails(shape):
    """Degenerate and prime-adjacent shapes against the default 256-blocks:
    every dimension exercises the pad-and-slice path."""
    M, K, N = shape
    x = _rand(M + K + N, (M, K), 2.0)
    q, scale = quant.quantize_int8(x)
    w = _rand(99, (K, N))
    _close(ops.dequant_matmul(q, scale, w),
           ref.dequant_matmul_ref(q, scale, w))


def test_dqmm_zero_amax_channels():
    """All-zero quantization groups: ``quantize_int8`` assigns scale 1.0
    (q is 0 there), so the corresponding output rows must be exactly 0."""
    x = _rand(21, (16, 24), 2.0)
    x = x.at[3].set(0.0).at[11].set(0.0)
    q, scale = quant.quantize_int8(x)
    w = _rand(22, (24, 6))
    got = ops.dequant_matmul(q, scale, w, block_m=8, block_n=8, block_k=8)
    _close(got, ref.dequant_matmul_ref(q, scale, w))
    assert np.all(np.asarray(got)[[3, 11]] == 0.0)


def test_dqmm_denormal_scales():
    """Sub-normal f32 scales (~1e-40): the in-register multiply must follow
    the reference through gradual underflow, not flush differently."""
    q = _rand(31, (12, 20), 40.0).astype(jnp.int8)
    scale = jnp.full((12, 1), 1e-40, jnp.float32)
    w = _rand(32, (20, 4))
    got = ops.dequant_matmul(q, scale, w, block_m=8, block_n=8, block_k=8)
    _close(got, ref.dequant_matmul_ref(q, scale, w))


def test_dqmm_near_overflow_magnitudes():
    """+-1e19-scale values: products reach ~1e38 (just inside f32 max).
    The f32 accumulator must match the reference without spurious inf."""
    q = jnp.asarray([[1, -2], [3, 4]], jnp.int8)
    scale = jnp.asarray([[1e19], [1e18]], jnp.float32)
    w = jnp.asarray([[1.0, -0.5], [0.25, 1.0]], jnp.float32)
    got = ops.dequant_matmul(q, scale, w, block_m=8, block_n=8, block_k=8)
    want = ref.dequant_matmul_ref(q, scale, w)
    assert np.all(np.isfinite(np.asarray(got)))
    _close(got, want)


def test_dqmm_out_dtype():
    q, scale = quant.quantize_int8(_rand(41, (16, 16)))
    w = _rand(42, (16, 16))
    got = ops.dequant_matmul(q, scale, w, out_dtype=jnp.bfloat16)
    assert got.dtype == jnp.bfloat16
    _close(got.astype(jnp.float32),
           ref.dequant_matmul_ref(q, scale, w, out_dtype=jnp.bfloat16
                                  ).astype(jnp.float32), 1e-2)


def test_dqmm_bad_scale_shape_raises():
    q = jnp.zeros((4, 8), jnp.int8)
    with pytest.raises(ValueError):
        normalize_scale(jnp.ones((4, 8, 1)), 4, 8)
    with pytest.raises(ValueError):
        normalize_scale(jnp.ones((3, 5)), 4, 8)  # matches neither M nor K


# ---------------------------------------------------------------------------
# dequant_matmul: custom_vjp gradients vs jax.grad of the reference
# ---------------------------------------------------------------------------


def test_dqmm_grad_matches_ref_linear_probe():
    """d/d(scale, w) of a linear probe of the output — must agree with
    ``jax.grad`` through the XLA reference (the backward IS the reference's
    vjp, so this checks the custom_vjp wiring end to end)."""
    x = _rand(51, (20, 28), 2.0)
    q, scale = quant.quantize_int8(x)
    w = _rand(52, (28, 12))
    probe = _rand(53, (20, 12))

    def f_pal(s, w_):
        return jnp.sum(probe * ops.dequant_matmul(
            q, s, w_, block_m=16, block_n=16, block_k=16))

    def f_ref(s, w_):
        return jnp.sum(probe * ref.dequant_matmul_ref(q, s, w_))

    gs_p, gw_p = jax.grad(f_pal, argnums=(0, 1))(scale, w)
    gs_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(scale, w)
    _close(gs_p, gs_r)
    _close(gw_p, gw_r)


def test_dqmm_grad_under_jit():
    q, scale = quant.quantize_int8(_rand(61, (16, 16), 2.0))
    w = _rand(62, (16, 16))
    probe = _rand(63, (16, 16))
    g_p = jax.jit(jax.grad(lambda w_: jnp.sum(
        probe * ops.dequant_matmul(q, scale, w_))))(w)
    g_r = jax.grad(lambda w_: jnp.sum(
        probe * ref.dequant_matmul_ref(q, scale, w_)))(w)
    _close(g_p, g_r)


def test_dqmm_vmap_over_cohort():
    """vmap over a leading client axis — the shape the fused round's
    vmap-over-clients lowering would present."""
    K, M, D, H = 3, 10, 14, 6
    qs, scales = [], []
    for i in range(K):
        qi, si = quant.quantize_int8(_rand(70 + i, (M, D), 2.0))
        qs.append(qi)
        scales.append(si)
    q = jnp.stack(qs)
    scale = jnp.stack(scales)
    w = _rand(80, (D, H))
    got = jax.vmap(lambda qq, ss: ops.dequant_matmul(
        qq, ss, w, block_m=8, block_n=8, block_k=8))(q, scale)
    want = jax.vmap(lambda qq, ss: ref.dequant_matmul_ref(qq, ss, w))(q, scale)
    _close(got, want)


def test_tiered_matmul_pallas_vs_xla():
    """``quant.tiered_matmul`` — the quant-aware consumer entry — agrees
    across backends and handles the float-tier ``x_scale=None`` case."""
    x = _rand(91, (18, 26), 2.0)
    q, scale = quant.quantize_int8(x)
    w = _rand(92, (26, 10))
    _close(quant.tiered_matmul(q, scale, w, use_pallas=True),
           quant.tiered_matmul(q, scale, w, use_pallas=False))
    _close(quant.tiered_matmul(x, None, w, use_pallas=True),
           quant.tiered_matmul(x, None, w, use_pallas=False))


# ---------------------------------------------------------------------------
# sparse_cohort_add: forward conformance
# ---------------------------------------------------------------------------


def _sparse_case(seed, K, k, L, weights=None):
    rng = np.random.RandomState(seed)
    idx = jnp.asarray(rng.randint(0, L, size=(K, k)), jnp.int32)
    vals = jnp.asarray(rng.randn(K, k), jnp.float32)
    w = (jnp.asarray(weights, jnp.float32) if weights is not None
         else jnp.asarray(rng.rand(K) + 0.1, jnp.float32))
    return idx, vals, w


def test_sparse_matches_ref_with_duplicates():
    idx, vals, w = _sparse_case(0, K=4, k=7, L=50)
    _close(ops.sparse_cohort_add(idx, vals, w, 50),
           ref.sparse_cohort_add_ref(idx, vals, w, 50))


@settings(max_examples=12, deadline=None)
@given(K=st.integers(1, 6), k=st.integers(1, 32),
       L=st.sampled_from([1, 8, 50, 400]), zero_w=st.booleans())
def test_sparse_shape_sweep(K, k, L, zero_w):
    """Hypothesis sweep: duplicate and out-of-order indices arise naturally
    from random draws; ``zero_w`` zeroes one client's Eq. 1 weight (a
    screened-out client must contribute exactly nothing)."""
    k = min(k, L)
    idx, vals, w = _sparse_case(K * 100 + k, K, k, L)
    if zero_w:
        w = w.at[0].set(0.0)
    _close(ops.sparse_cohort_add(idx, vals, w, L),
           ref.sparse_cohort_add_ref(idx, vals, w, L))


def test_sparse_all_clients_same_index():
    """Worst-case collision: every (client, slot) hits one index — the
    serialized read-modify-write loop must accumulate all K*k terms."""
    K, k, L = 5, 9, 30
    idx = jnp.full((K, k), 17, jnp.int32)
    vals = jnp.asarray(np.random.RandomState(1).randn(K, k), jnp.float32)
    w = jnp.asarray(np.random.RandomState(2).rand(K), jnp.float32)
    got = ops.sparse_cohort_add(idx, vals, w, L)
    _close(got, ref.sparse_cohort_add_ref(idx, vals, w, L))
    assert float(jnp.sum(got != 0)) == 1.0


def test_sparse_adversarial_values():
    """Denormals, +-1e30 magnitudes, and exact negatives in one payload."""
    idx = jnp.asarray([[0, 1, 2, 2], [2, 0, 3, 3]], jnp.int32)
    vals = jnp.asarray([[1e-40, 1e30, 5.0, -5.0],
                        [-1e30, 2e-40, 7.5, -7.5]], jnp.float32)
    w = jnp.asarray([1.0, 1.0], jnp.float32)
    _close(ops.sparse_cohort_add(idx, vals, w, 4),
           ref.sparse_cohort_add_ref(idx, vals, w, 4))


def test_sparse_large_length_falls_back_to_ref(monkeypatch):
    """The documented dispatch rule: a dense block too large for VMEM
    residency routes to the XLA scatter reference — bitwise, because the
    fallback IS the reference."""
    idx, vals, w = _sparse_case(5, K=3, k=4, L=64)
    monkeypatch.setattr(sparse_agg, "MAX_VMEM_ELEMS", 32)
    got = ops.sparse_cohort_add(idx, vals, w, 64)
    want = ref.sparse_cohort_add_ref(idx, vals, w, 64)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_sparse_under_jit():
    idx, vals, w = _sparse_case(6, K=4, k=6, L=40)
    got = jax.jit(lambda i, v, ww: ops.sparse_cohort_add(i, v, ww, 40)
                  )(idx, vals, w)
    _close(got, ref.sparse_cohort_add_ref(idx, vals, w, 40))


# ---------------------------------------------------------------------------
# compression-layer integration
# ---------------------------------------------------------------------------


def test_ingraph_sparse_aggregate_flag_parity():
    idx, vals, w = _sparse_case(7, K=5, k=8, L=100)
    _close(ingraph_sparse_aggregate(idx, vals, w, 100, use_pallas=True),
           ingraph_sparse_aggregate(idx, vals, w, 100, use_pallas=False))


def test_ingraph_compress_leaf_parity():
    """Full leaf pipeline (delta + error feedback -> top-k -> fold): the
    selection and residual math are shared, so idx/vals/residuals must be
    IDENTICAL across backends and only the aggregation differs by
    accumulation noise."""
    K, L = 4, 120
    rng = np.random.RandomState(8)
    start = jnp.asarray(rng.randn(L), jnp.float32)
    end = jnp.asarray(rng.randn(K, L) * 0.1 + np.asarray(start), jnp.float32)
    residual = jnp.asarray(rng.randn(K, L) * 0.01, jnp.float32)
    w = jnp.asarray(rng.rand(K) + 0.1, jnp.float32)
    agg_p, res_p, idx_p, vals_p = ingraph_compress_leaf(
        start, end, residual, w, 0.1, use_pallas=True)
    agg_x, res_x, idx_x, vals_x = ingraph_compress_leaf(
        start, end, residual, w, 0.1, use_pallas=False)
    assert np.array_equal(np.asarray(idx_p), np.asarray(idx_x))
    assert np.array_equal(np.asarray(vals_p), np.asarray(vals_x))
    assert np.array_equal(np.asarray(res_p), np.asarray(res_x))
    _close(agg_p, agg_x)


# ---------------------------------------------------------------------------
# fused-round and server integration (use_pallas=True vs XLA default)
# ---------------------------------------------------------------------------


def _mlp_world(seed=0, K=3, nb=2, B=8, D=12, H=8, C=4):
    rng = np.random.RandomState(seed)
    params = {"w1": jnp.asarray(rng.randn(D, H) * 0.3, jnp.float32),
              "b1": jnp.zeros((H,), jnp.float32),
              "w2": jnp.asarray(rng.randn(H, C) * 0.3, jnp.float32)}
    batches = {"x": jnp.asarray(rng.randn(K, nb, B, D), jnp.float32),
               "y": jnp.asarray(rng.randint(0, C, size=(K, nb, B)), jnp.int32)}
    nb_live = jnp.full((K,), nb, jnp.int32)
    weights = jnp.ones((K,), jnp.float32) / K
    return params, batches, nb_live, weights


def _mlp_loss(params, frozen, state, batch):
    h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=1)
    return jnp.mean(nll), state


@pytest.mark.parametrize("unroll", [True, False])
def test_fused_compressed_round_pallas_parity(unroll):
    """The tentpole wiring: a compressed fused round with the Pallas cohort
    fold reproduces the XLA scatter round on params, residuals and losses —
    for both the unrolled (CPU) and vmap lowerings."""
    params, batches, nb_live, weights = _mlp_world()
    K = int(nb_live.shape[0])
    residuals = jax.tree.map(
        lambda l: jnp.zeros((K, l.size), jnp.float32), params)

    def run(use_pallas):
        fn = make_fused_round(_mlp_loss, sgd(0.05), compress_ratio=0.3,
                              unroll=unroll, use_pallas=use_pallas)
        return fn(params, {}, {}, batches, nb_live, weights, residuals)

    p_p, _, l_p, r_p = run(True)
    p_x, _, l_x, r_x = run(False)
    _close(l_p, l_x)
    for a, b in zip(jax.tree.leaves(p_p), jax.tree.leaves(p_x)):
        _close(a, b)
    for a, b in zip(jax.tree.leaves(r_p), jax.tree.leaves(r_x)):
        _close(a, b)


def test_quant_aware_int8_round_pallas_parity():
    """int8 tier + quant-aware consumer: the batch keeps (x int8, x_scale)
    and the loss routes its leading GEMM through ``tiered_matmul``; the
    Pallas in-register dequant round must track the materializing XLA
    round across both lowerings."""
    params, batches, nb_live, weights = _mlp_world(seed=1)
    K, nb = batches["x"].shape[:2]
    qs = np.zeros(batches["x"].shape, np.int8)
    ss = np.zeros(batches["x"].shape[:3] + (1,), np.float32)
    for ki in range(K):
        for ni in range(nb):
            qb, sb = quant.quantize_int8(batches["x"][ki, ni])
            qs[ki, ni] = np.asarray(qb)
            ss[ki, ni] = np.asarray(sb)
    qbatches = {"x": jnp.asarray(qs), "x_scale": jnp.asarray(ss),
                "y": batches["y"]}

    def consumer(params, frozen, state, batch):
        h = jnp.tanh(quant.tiered_matmul(
            batch["x"], batch.get("x_scale"), params["w1"],
            use_pallas=batch.get("use_pallas", False)) + params["b1"])
        logp = jax.nn.log_softmax(h @ params["w2"])
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=1)
        return jnp.mean(nll), state

    consumer.consumes_quantized = True

    def run(use_pallas, unroll):
        loss = quant.make_tiered_loss(consumer, "int8",
                                      use_pallas=use_pallas)
        fn = make_fused_round(loss, sgd(0.05), unroll=unroll)
        return fn(params, {}, {}, qbatches, nb_live, weights)

    ref_p, _, ref_l = run(False, True)
    for use_pallas, unroll in [(True, True), (True, False), (False, False)]:
        p, _, losses = run(use_pallas, unroll)
        _close(losses, ref_l)
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(ref_p)):
            _close(a, b)


@pytest.mark.slow
def test_e2e_smartfreeze_two_stage_pallas_trajectory():
    """Acceptance headline: a 2-stage SmartFreeze CNN trajectory with
    compressed uplinks runs entirely through the Pallas cohort fold
    (``SmartFreezeServer(use_pallas=True)``) and stays allclose (f32) to
    the XLA-default twin — params, per-round losses, and uplink bytes."""
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.server import SmartFreezeServer
    from repro.models.cnn import CNN, CNNConfig

    sv = SyntheticVision(num_classes=4, image_size=8)
    train = sv.sample(128, seed=1)
    parts = dirichlet_partition(train["y"], 6, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1, 1), stage_channels=(4, 8),
                    num_classes=4)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))

    def run(use_pallas):
        srv = SmartFreezeServer(model, clients, clients_per_round=4,
                                batch_size=16, rounds_per_stage=2,
                                compress_ratio=0.2, seed=0,
                                pace_kwargs=dict(min_rounds=999),
                                use_pallas=use_pallas)
        return srv.run(params, state, total_rounds=4)

    out_p, out_x = run(True), run(False)
    assert len(out_p["history"]) == len(out_x["history"]) == 4
    stages = [r.stage for r in out_p["history"]]
    assert len(set(stages)) >= 2  # the trajectory really crossed a freeze
    for rp, rx in zip(out_p["history"], out_x["history"]):
        assert rp.stage == rx.stage
        assert rp.uplink_bytes == rx.uplink_bytes
        _close(rp.loss, rx.loss, 1e-4)
    for a, b in zip(jax.tree.leaves(out_p["params"]),
                    jax.tree.leaves(out_x["params"])):
        _close(a, b, 1e-4)


# ---------------------------------------------------------------------------
# dispatch plumbing
# ---------------------------------------------------------------------------


def test_interpret_default_is_container_aware():
    """``ops`` wrappers pass ``interpret=None`` -> backend probe: True off
    TPU (this CI container is CPU-only, so the kernel bodies actually
    execute via the Pallas interpreter here), False on real TPUs."""
    want = jax.default_backend() != "tpu"
    assert ops._default_interpret() is want
    assert want is True  # this suite runs on the CPU container


def test_use_pallas_rejects_sharded_mesh():
    """The engine guard: the Pallas cohort fold is single-device; a real
    multi-device client mesh must be refused loudly, not silently wrong."""
    n_dev = jax.device_count()
    if n_dev < 2:
        class _FakeMesh:
            pass
        import repro.fl.engine as eng
        orig = eng.client_axis_size
        eng.client_axis_size = lambda m: 4
        try:
            with pytest.raises(ValueError, match="use_pallas"):
                make_fused_round(_mlp_loss, sgd(0.1), mesh=_FakeMesh(),
                                 use_pallas=True)
        finally:
            eng.client_axis_size = orig
    else:
        from repro.launch.mesh import make_client_mesh
        with pytest.raises(ValueError, match="use_pallas"):
            make_fused_round(_mlp_loss, sgd(0.1),
                             mesh=make_client_mesh(n_dev), use_pallas=True)


@pytest.mark.slow
def test_lm_attention_impl_pallas_matches_xla():
    """``ArchConfig.attention_impl="pallas"`` (the ``--use-pallas`` launch
    route) sends GQA full-sequence attention through the flash kernel; loss
    and grads on a reduced f32 LM must track the XLA attention graph."""
    import dataclasses

    from repro import configs
    from repro.data.synthetic import make_lm_batch
    from repro.models.transformer import build

    base = configs.get("llama3-8b").reduced(num_layers=2)
    base = dataclasses.replace(base, param_dtype="float32",
                               compute_dtype="float32")
    batch = None
    out = {}
    for impl in ("xla", "pallas"):
        cfg = dataclasses.replace(base, attention_impl=impl)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        if batch is None:
            batch = {k: jnp.asarray(v)
                     for k, v in make_lm_batch(cfg, 2, 48, 0).items()}
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        out[impl] = (float(loss), grads)
    assert abs(out["pallas"][0] - out["xla"][0]) <= 1e-5 * max(
        1.0, abs(out["xla"][0]))
    for gp, gx in zip(jax.tree.leaves(out["pallas"][1]),
                      jax.tree.leaves(out["xla"][1])):
        _close(gp, gx, 1e-4)
