"""Fault tolerance (ISSUE 7): injection determinism, screening identity,
durable checkpoints.

Three layers:

  * ``fl/faults.py`` — the deterministic schedule must be a pure function of
    (seed, round, client id): independent of cohort iteration order, subset
    membership, and call history;
  * ``fl/engine.py`` + ``fl/server.py`` — with every defense armed and ZERO
    faults injected, trajectories must be bit-for-bit identical to the
    undefended run (f32, fused and sequential paths; the 8-device sharded
    variant lives in tests/_shard_driver.py). With faults, corrupted updates
    are screened out of Eq. 1 and the aggregate stays finite;
  * ``checkpoint/ckpt.py`` — crc-verified restores fall back to the previous
    committed step on corruption or torn directories, and async save
    failures re-raise instead of masquerading as committed.
"""
import json
import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import (CheckpointCorruptError, CheckpointManager,
                              latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.core import freezing_cnn as fz
from repro.core.pace import PaceController
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticVision
from repro.fl.client import make_client_fleet
from repro.fl.engine import RoundEngine
from repro.fl.faults import FaultInjector, apply_fault_to_update, hash_draws
from repro.fl.server import SmartFreezeServer, _mean_loss
from repro.fl.sim import AsyncBufferedAggregation, FederatedLoop
from repro.models.cnn import CNN, CNNConfig
from repro.optim import sgd

TINY = CNNConfig("tiny_resnet", "resnet", stage_sizes=(1, 1),
                 stage_channels=(8, 16), num_classes=4)


# ---------------------------------------------------------------------------
# deterministic fault schedule
# ---------------------------------------------------------------------------


def test_schedule_order_and_subset_independent():
    inj = FaultInjector(p_fault=0.4, kinds=("nan", "signflip", "crash"),
                        seed=11)
    cohort = list(range(40))
    fwd = inj.schedule(cohort, 7)
    rev = inj.schedule(list(reversed(cohort)), 7)
    assert fwd == rev
    # membership in the cohort must not perturb other clients' draws
    sub = inj.schedule(cohort[::3], 7)
    assert all(fwd.get(c) == sub.get(c) for c in cohort[::3])
    # per-client single-draw API agrees with the batch API
    assert all(inj.fault_for(c, 7) == fwd.get(c) for c in cohort)


def test_schedule_history_independent_and_seeded():
    a = FaultInjector(p_fault=0.5, kinds=("nan",), seed=3)
    b = FaultInjector(p_fault=0.5, kinds=("nan",), seed=3)
    # consume a in a different order than b — draws must not drift
    a.schedule(range(10), 0)
    a.schedule(range(10), 5)
    assert a.schedule(range(10), 2) == b.schedule(range(10), 2)
    c = FaultInjector(p_fault=0.5, kinds=("nan",), seed=4)
    assert any(b.schedule(range(50), r) != c.schedule(range(50), r)
               for r in range(4))


def test_schedule_rate_and_start_round():
    inj = FaultInjector(p_fault=0.3, kinds=("nan",), seed=0, start_round=5)
    assert inj.schedule(range(100), 4) == {}
    hits = sum(len(inj.schedule(range(100), r)) for r in range(5, 25))
    assert 0.2 < hits / 2000 < 0.4
    assert FaultInjector(p_fault=0.0, seed=0).schedule(range(100), 9) == {}


def test_hash_draws_uniform():
    u = hash_draws(0, 3, np.arange(4000))
    assert u.shape == (4000,) and (0 <= u).all() and (u < 1).all()
    assert abs(u.mean() - 0.5) < 0.03


def test_apply_fault_kinds():
    p0 = {"w": np.ones(4, np.float32)}
    p1 = {"w": np.full(4, 3.0, np.float32)}
    nan = apply_fault_to_update("nan", p0, p1)
    assert np.isnan(np.asarray(nan["w"])).all()
    inf = apply_fault_to_update("inf", p0, p1)
    assert np.isinf(np.asarray(inf["w"])).all()
    # signflip negates the DELTA around the round-start params
    flip = apply_fault_to_update("signflip", p0, p1)
    assert np.allclose(np.asarray(flip["w"]), -1.0)  # 1 - (3-1)
    amp = apply_fault_to_update("amplify", p0, p1, amplify=10.0)
    assert np.allclose(np.asarray(amp["w"]), 1 + 10 * 2.0)


# ---------------------------------------------------------------------------
# engine: screening identity + fault masking
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def world():
    sv = SyntheticVision(num_classes=4, image_size=16, seed=0)
    train = sv.sample(400, seed=1)
    parts = dirichlet_partition(train["y"], 6, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    model = CNN(TINY)
    params, state = model.init(jax.random.PRNGKey(0))
    frozen, active = fz.init_cnn_stage_active(model, params, 0,
                                              jax.random.PRNGKey(1))
    return {c.client_id: c for c in clients}, model, frozen, active, state


def _engine(model, frozen, **kw):
    return RoundEngine(loss_fn=fz.cnn_stage_loss_fn(model, 0),
                       optimizer=sgd(0.05), frozen=frozen, batch_size=32,
                       local_epochs=1, **kw)


def _tree_bytes(t):
    return b"".join(np.asarray(x).tobytes() for x in jax.tree.leaves(t))


@pytest.mark.parametrize("sequential", [False, True])
def test_zero_fault_screening_bit_identity(world, sequential):
    """All defenses on, no faults -> BIT-identical round (f32)."""
    by_id, model, frozen, active, state = world
    sel = sorted(by_id)[:4]
    a0, s0, l0 = _engine(model, frozen).run_round(
        by_id, sel, active, state, 3, sequential=sequential)
    e1 = _engine(model, frozen, screen=True)
    a1, s1, l1 = e1.run_round(by_id, sel, active, state, 3,
                              sequential=sequential)
    assert _tree_bytes(a0) == _tree_bytes(a1)
    assert _tree_bytes(s0) == _tree_bytes(s1)
    assert l0 == l1
    assert e1.last_screened == {c: False for c in sel}


@pytest.mark.parametrize("kind", ["nan", "inf", "amplify"])
def test_corrupted_update_screened(world, kind):
    by_id, model, frozen, active, state = world
    sel = sorted(by_id)[:4]
    e = _engine(model, frozen, screen=True)
    a, s, losses = e.run_round(by_id, sel, active, state, 3,
                               faults={sel[0]: kind})
    assert e.last_screened[sel[0]] is True
    assert not any(e.last_screened[c] for c in sel[1:])
    for x in jax.tree.leaves((a, s)):
        assert np.isfinite(np.asarray(x)).all()


def test_signflip_needs_robust_aggregator(world):
    """A sign-flipped delta is norm-preserving: the screen cannot see it,
    the coordinate-median aggregator is the defense layer that can."""
    by_id, model, frozen, active, state = world
    sel = sorted(by_id)[:4]
    e = _engine(model, frozen, screen=True)
    e.run_round(by_id, sel, active, state, 3, faults={sel[0]: "signflip"})
    assert e.last_screened[sel[0]] is False
    er = _engine(model, frozen, aggregator="coord_median")
    a, s, _ = er.run_round(by_id, sel, active, state, 3,
                           faults={sel[0]: "nan"})
    for x in jax.tree.leaves((a, s)):
        assert np.isfinite(np.asarray(x)).all()


def test_all_screened_round_is_noop(world):
    by_id, model, frozen, active, state = world
    sel = sorted(by_id)[:3]
    e = _engine(model, frozen, screen=True)
    a, s, _ = e.run_round(by_id, sel, active, state, 3,
                          faults={c: "nan" for c in sel})
    assert _tree_bytes(a) == _tree_bytes(active)
    assert _tree_bytes(s) == _tree_bytes(state)


def test_server_zero_fault_defended_bit_identity(world):
    """Full SmartFreeze run, every defense armed, no injector: trajectory
    must match the undefended server bit-for-bit (acceptance criterion)."""
    by_id, model, frozen, active, state = world
    clients = list(by_id.values())
    params, st = model.init(jax.random.PRNGKey(0))

    def run(**kw):
        srv = SmartFreezeServer(model, clients, clients_per_round=4,
                                batch_size=32, rounds_per_stage=2, seed=0,
                                pace_kwargs=dict(min_rounds=99), **kw)
        out = srv.run(params, st, schedule=[2, 2])
        return out, srv

    out0, srv0 = run()
    out1, srv1 = run(screen_updates=True, freeze_rollback=True,
                     faults=FaultInjector(p_fault=0.0))
    assert _tree_bytes(out0["params"]) == _tree_bytes(out1["params"])
    assert [r.loss for r in srv0.history] == [r.loss for r in srv1.history]
    assert [r.selected for r in srv0.history] == \
        [r.selected for r in srv1.history]
    assert all(not r.screened and not r.rolled_back for r in srv1.history)


# ---------------------------------------------------------------------------
# sim: crash semantics + async watchdog
# ---------------------------------------------------------------------------


def test_sync_crash_drops_update_charges_time():
    calls = []

    def train_fn(cohort, r, sequential=None, faults=None):
        calls.append(list(cohort))
        return {c: 1.0 for c in cohort}

    from repro.fl.sim import FleetTimeModel
    tm = FleetTimeModel(client_ids=np.arange(5),
                        compute_s=np.full(5, 2.0, np.float32),
                        link_rate=np.full(5, np.inf, np.float32))
    loop = FederatedLoop(select_fn=lambda r, a: a[:4], train_fn=train_fn,
                         client_ids=[0, 1, 2, 3, 4], time_model=tm,
                         faults=FaultInjector(p_fault=1.0, kinds=("crash",)))
    rec = loop.run(1)[0]
    assert rec.selected == [] and sorted(rec.dropped) == [0, 1, 2, 3]
    assert rec.losses == {} and calls == []          # updates lost
    assert rec.duration > 0                          # compute still spent
    assert set(rec.faults) == {0, 1, 2, 3}


def test_async_hang_watchdog_redispatches():
    model = [{"w": np.float32(1.0)}]
    pol = AsyncBufferedAggregation(buffer_size=2, concurrency=3,
                                   timeout_s=5.0, max_retries=1)
    loop = FederatedLoop(
        select_fn=lambda r, a: a, train_fn=lambda *a, **k: {},
        client_ids=[0, 1, 2, 3], aggregation=pol,
        snapshot_fn=lambda: (model[0], {}),
        train_one_fn=lambda cid, p, s, r: ({"w": p["w"] - 0.1}, {}, 0.5),
        get_model_fn=lambda: (model[0], {}),
        set_model_fn=lambda p, s: model.__setitem__(0, p),
        faults=FaultInjector(p_fault=1.0, kinds=("hang",), seed=3))
    recs = loop.run(2)
    assert all(np.isfinite(r.t_end) for r in recs)   # clock never hangs
    assert any(r.retries for r in recs)
    assert np.isfinite(np.asarray(model[0]["w"])).all()


def test_mean_loss_starved_round():
    assert _mean_loss({1: 0.5, 2: 1.5}) == 1.0
    assert _mean_loss({1: float("nan"), 2: 1.0}) == 1.0
    assert _mean_loss({1: float("nan")}, prev=0.7) == 0.7
    assert _mean_loss({}, prev=0.7) == 0.7


def test_pace_rejects_nonfinite_observation():
    pc = PaceController(window_q=3, smooth_h=2)
    good = {"w": np.ones(4, np.float32)}
    for i in range(4):
        pc.observe(jax.tree.map(lambda x: x * (1 + 0.1 * i), good))
    before = pc.history["smoothed"][-1]
    out = pc.observe({"w": np.full(4, np.nan, np.float32)})
    assert out == before                       # returns last smoothed value
    assert pc.history["rounds"] == 4 and pc.history["skipped"] == 1
    # round-trips through the checkpoint counters
    pc2 = PaceController(window_q=3, smooth_h=2).load_state_dict(
        pc.state_dict())
    assert pc2.history["skipped"] == 1


# ---------------------------------------------------------------------------
# checkpoint durability
# ---------------------------------------------------------------------------


def _tree(v):
    return {"a": np.full((2, 3), v, np.float32),
            "b": {"c": np.arange(4, dtype=np.float32) + v}}


def test_restore_falls_back_on_crc_mismatch(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1.0))
    save_checkpoint(d, 2, _tree(2.0))
    leaf = os.path.join(d, "step_2", "a.npy")
    arr = np.load(leaf)
    arr[0, 0] += 1.0
    np.save(leaf, arr)
    out = restore_checkpoint(d)
    assert out["step"] == 1
    assert np.array_equal(out["tree"]["a"], _tree(1.0)["a"])
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(d, step=2)   # explicit step: no silent substitute


def test_torn_step_dir_skipped(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1.0))
    save_checkpoint(d, 2, _tree(2.0))
    save_checkpoint(d, 3, _tree(3.0))
    os.remove(os.path.join(d, "step_3", "manifest.json"))   # torn manifest
    os.remove(os.path.join(d, "step_2", "a.npy"))           # torn leaf
    assert latest_step(d) == 1
    assert restore_checkpoint(d)["step"] == 1


def test_manifest_without_crc_still_restores(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1.0))
    man = os.path.join(d, "step_1", "manifest.json")
    with open(man) as f:
        m = json.load(f)
    for e in m["leaves"]:
        e.pop("crc32", None)            # pre-ISSUE-7 checkpoint layout
    with open(man, "w") as f:
        json.dump(m, f)
    out = restore_checkpoint(d, step=1)
    assert np.array_equal(out["tree"]["b"]["c"], _tree(1.0)["b"]["c"])


def test_async_save_failure_reraises(tmp_path):
    import shutil
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=2)
    mgr.save(1, _tree(1.0))
    mgr.wait()
    shutil.rmtree(d)
    with open(d, "w") as f:       # a FILE where the dir should be
        f.write("x")
    mgr.save(2, _tree(2.0))
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.wait()
    mgr.wait()                    # error is consumed, not sticky
    os.remove(d)


# ---------------------------------------------------------------------------
# robust-aggregator properties (hypothesis): _robust_leaf / _keep_mask
# ---------------------------------------------------------------------------


def _leaf_case(seed, K, shape=(5,)):
    import jax.numpy as jnp
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.uniform(-1.0, 1.0, size=(K,) + shape), jnp.float32)
    return x


@settings(max_examples=10, deadline=None)
@given(K=st.integers(3, 9), seed=st.integers(0, 1000),
       agg=st.sampled_from(["coord_median", "trimmed_mean"]))
def test_robust_leaf_permutation_invariant(K, seed, agg):
    """Both combines are order statistics over the kept rows, so any client
    permutation leaves the result BITWISE unchanged (the sort erases row
    order) — cohort ordering can never leak into a robust aggregate."""
    import jax.numpy as jnp
    from repro.fl.engine import _robust_leaf
    x = _leaf_case(seed, K)
    keep = jnp.asarray(np.random.RandomState(seed + 1).rand(K) > 0.3)
    keep = keep.at[0].set(True)  # at least one valid row
    n_valid = jnp.sum(keep.astype(jnp.int32))
    perm = np.random.RandomState(seed + 2).permutation(K)
    a = _robust_leaf(x, keep, n_valid, agg, 0.2)
    b = _robust_leaf(x[perm], keep[perm], n_valid, agg, 0.2)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=10, deadline=None)
@given(K=st.integers(4, 9), seed=st.integers(0, 1000),
       agg=st.sampled_from(["coord_median", "trimmed_mean"]))
def test_robust_leaf_bounded_under_minority_outliers(K, seed, agg):
    """Breakdown property: with clean rows in [-1, 1] and a tolerable
    minority of kept-but-corrupted rows at +-1e6 (fewer than half for the
    median, at most floor(beta * n) for the trimmed mean), the aggregate
    stays inside the clean envelope — the outliers are order-statistically
    discarded, not averaged in."""
    import jax.numpy as jnp
    from repro.fl.engine import _robust_leaf
    beta = 0.25
    n_bad = ((K - 1) // 2 if agg == "coord_median"
             else int(np.floor(beta * K)))
    x = _leaf_case(seed, K)
    rng = np.random.RandomState(seed + 3)
    bad_rows = rng.choice(K, size=n_bad, replace=False)
    for r in bad_rows:
        x = x.at[r].set(1e6 * (1 if rng.rand() < 0.5 else -1))
    keep = jnp.ones((K,), bool)
    out = np.asarray(_robust_leaf(x, keep, jnp.asarray(K, jnp.int32),
                                  agg, beta))
    assert np.all(np.abs(out) <= 1.0 + 1e-6), (agg, n_bad, out)


@settings(max_examples=10, deadline=None)
@given(K=st.integers(2, 8), seed=st.integers(0, 1000))
def test_keep_mask_zero_fault_identity(K, seed):
    """The screening contract's unit form: all-finite losses and deltas
    with norms under the median multiplier keep EVERY row, and masking the
    Eq. 1 weights through the all-true mask is bitwise the identity."""
    import jax.numpy as jnp
    from repro.fl.engine import _keep_mask
    rng = np.random.RandomState(seed)
    norms = jnp.asarray(rng.uniform(0.5, 1.5, size=K), jnp.float32)
    losses = jnp.asarray(rng.uniform(0.1, 3.0, size=K), jnp.float32)
    weights = jnp.asarray(rng.rand(K) + 0.1, jnp.float32)
    mask = _keep_mask(norms, losses, weights, mult=8.0)
    assert bool(jnp.all(mask))
    masked = jnp.where(mask, weights, 0.0)
    assert np.array_equal(np.asarray(masked), np.asarray(weights))
