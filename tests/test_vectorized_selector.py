"""Vectorized population selector vs the list-based reference, sketch
similarity + label propagation vs the dense Louvain/RL-CD oracle, and the
selector-seed regression (PR 2)."""
import numpy as np
import pytest

from repro.core.selector import (ClientInfo, ClientPopulation,
                                 ParticipantSelector, VectorizedSelector,
                                 label_propagation, louvain,
                                 population_from_selector, sketch_communities,
                                 similarity_matrix, topm_neighbors)
from repro.core.selector.selection import InfeasibleStageError
from repro.core.selector.similarity import label_sketches, sketch_projection


def _fleet(n=40, seed=0):
    rng = np.random.RandomState(seed)
    return {i: ClientInfo(i, memory_bytes=float(rng.choice([1, 2, 4, 8])) * 2**30,
                          capability=float(rng.choice([1e9, 2.5e9])),
                          num_samples=int(rng.randint(10, 200)),
                          loss_sum=float(rng.rand())) for i in range(n)}


def _clustered_sim(n_groups=3, per=6, seed=0):
    rng = np.random.RandomState(seed)
    vecs = {}
    for g in range(n_groups):
        proto = np.zeros(48)
        proto[g * 16:(g + 1) * 16] = 1.0
        for i in range(per):
            vecs[g * per + i] = proto + rng.randn(48) * 0.05
    return similarity_matrix(vecs), n_groups, per


def _time_fn(c):
    return c.num_samples / c.capability


# ---------------------------------------------------------------------------
# vectorized vs list-based selector (same picks, same RNG, epsilon=0)
# ---------------------------------------------------------------------------


def test_vectorized_matches_list_no_communities():
    clients = _fleet()
    ls = ParticipantSelector(epsilon=0.0, seed=3)
    vs = VectorizedSelector(epsilon=0.0, seed=3)
    for _ in range(4):
        for k in (3, 7, 15):
            pa = ls.select(clients, k, mem_required=1.5 * 2**30,
                           stage_time_fn=_time_fn)
            pb = vs.select(clients, k, mem_required=1.5 * 2**30,
                           stage_time_fn=_time_fn)
            assert pa == pb


def test_vectorized_matches_list_when_k_exceeds_eligible():
    """Regression: when k >= the eligible count, the list path's
    ``bandit.pick`` early-returns the candidates in original order rather
    than by utility — the pick ORDER must still match."""
    clients = {0: ClientInfo(0, 2**33, 1e9, 10, loss_sum=1.0),
               1: ClientInfo(1, 2**33, 1e9, 10, loss_sum=2.0),
               2: ClientInfo(2, 2**33, 1e9, 10, loss_sum=9.0)}
    for k in (3, 5):
        ls = ParticipantSelector(epsilon=0.0, seed=0)
        vs = VectorizedSelector(epsilon=0.0, seed=0)
        pa = ls.select(clients, k, mem_required=0, stage_time_fn=_time_fn)
        pb = vs.select(clients, k, mem_required=0, stage_time_fn=_time_fn)
        assert pa == pb == [0, 1, 2]


def test_vectorized_matches_list_with_shuffled_dict_order():
    """Regression: without communities, the list path's candidate order is
    the clients dict's INSERTION order (it drives tie-breaks and the
    k >= #eligible early return) — the adapter must mirror it, not sort."""
    base = _fleet(20, seed=4)
    order = np.random.RandomState(0).permutation(20)
    clients = {int(i): base[int(i)] for i in order}      # shuffled insertion
    for k in (5, 50):                                     # both regimes
        ls = ParticipantSelector(epsilon=0.0, seed=2)
        vs = VectorizedSelector(epsilon=0.0, seed=2)
        pa = ls.select(clients, k, mem_required=1.5 * 2**30,
                       stage_time_fn=_time_fn)
        pb = vs.select(clients, k, mem_required=1.5 * 2**30,
                       stage_time_fn=_time_fn)
        assert pa == pb


def test_vectorized_matches_list_with_communities():
    W, ng, per = _clustered_sim()
    rng = np.random.RandomState(1)
    clients = {i: ClientInfo(i, memory_bytes=2**33, capability=1e9,
                             num_samples=10 + i, loss_sum=float(rng.rand()))
               for i in range(ng * per)}
    ls = ParticipantSelector(epsilon=0.0, seed=5, phi=1)
    vs = VectorizedSelector(epsilon=0.0, seed=5, phi=1)
    assert ls.fit_communities(W) == vs.fit_communities(W)
    for _ in range(5):
        for k in (ng, ng + 2, 2 * ng + 1):
            pa = ls.select(clients, k, mem_required=0, stage_time_fn=_time_fn)
            pb = vs.select(clients, k, mem_required=0, stage_time_fn=_time_fn)
            assert pa == pb


def test_vectorized_matches_list_under_memory_filter():
    """Eq. 12/14: eligibility masks agree and partial-eligibility pools
    (exhaustion re-permutes) still track the list path exactly."""
    W, ng, per = _clustered_sim(per=5)
    clients = {i: ClientInfo(i, memory_bytes=(2.0 if i % 3 else 0.5) * 2**30,
                             capability=1e9, num_samples=20 + i,
                             loss_sum=float(i % 7))
               for i in range(ng * per)}
    ls = ParticipantSelector(epsilon=0.0, seed=11, phi=1)
    vs = VectorizedSelector(epsilon=0.0, seed=11, phi=1)
    ls.fit_communities(W)
    vs.fit_communities(W)
    for _ in range(4):
        pa = ls.select(clients, 8, mem_required=2**30, stage_time_fn=_time_fn)
        pb = vs.select(clients, 8, mem_required=2**30, stage_time_fn=_time_fn)
        assert pa == pb
        assert all(clients[c].memory_bytes >= 2**30 for c in pb)


def test_vectorized_infeasible_raises():
    clients = _fleet()
    vs = VectorizedSelector(phi=3)
    with pytest.raises(InfeasibleStageError):
        vs.select(clients, 4, mem_required=64 * 2**30, stage_time_fn=_time_fn)


def test_single_community_excludes_unassigned_clients():
    """Regression: with one fitted community, the top-k fast path must not
    pick eligible clients OUTSIDE that community (the list path's pools
    never contain them) — picks stay identical to the list selector."""
    clients = {0: ClientInfo(0, 2**33, 1e9, 10, loss_sum=1.0),
               1: ClientInfo(1, 2**33, 1e9, 10, loss_sum=2.0),
               2: ClientInfo(2, 2**33, 1e9, 10, loss_sum=9.0)}  # best util
    for k in (1, 2, 3):
        ls = ParticipantSelector(epsilon=0.0, seed=0, phi=1)
        vs = VectorizedSelector(epsilon=0.0, seed=0, phi=1)
        ls._communities = [[0, 1]]               # client 2 unassigned
        vs._communities = [[0, 1]]
        pa = ls.select(clients, k, mem_required=0, stage_time_fn=_time_fn)
        pb = vs.select(clients, k, mem_required=0, stage_time_fn=_time_fn)
        assert pa == pb
        assert 2 not in pb


def test_infeasible_round_does_not_desync_rng_streams():
    """Regression: a caught InfeasibleStageError must not advance the
    vectorized round counter (the list selector raises before its bandit's
    next_round), or every later round's permutation stream diverges."""
    W, ng, per = _clustered_sim(n_groups=4, per=6)
    clients = {i: ClientInfo(i, 2**30, 1e9, 10 + i, loss_sum=float(i % 5))
               for i in range(ng * per)}
    ls = ParticipantSelector(epsilon=0.0, seed=9, phi=2)
    vs = VectorizedSelector(epsilon=0.0, seed=9, phi=2)
    ls.fit_communities(W)
    vs.fit_communities(W)
    for r in range(6):
        if r == 2:   # an infeasible stage round in the middle
            for s in (ls, vs):
                with pytest.raises(InfeasibleStageError):
                    s.select(clients, 4, mem_required=2**40,
                             stage_time_fn=_time_fn)
            continue
        pa = ls.select(clients, 4, mem_required=0, stage_time_fn=_time_fn)
        pb = vs.select(clients, 4, mem_required=0, stage_time_fn=_time_fn)
        assert pa == pb, r


def test_population_roundtrip_and_snapshot():
    clients = _fleet(17)
    pop = ClientPopulation.from_infos(clients)
    assert pop.n == 17
    assert list(pop.client_ids) == sorted(clients)
    np.testing.assert_allclose(
        np.asarray(pop.memory_bytes),
        [clients[c].memory_bytes for c in sorted(clients)])
    sel = ParticipantSelector()
    pop2 = population_from_selector(sel, clients)
    assert pop2.n_communities == 1
    pop2.update_loss_sums([0, 3], [5.0, 7.0])
    assert float(pop2.loss_sum[3]) == 7.0


def test_select_arrays_resident_population():
    """The population-scale entry point: device-resident arrays, explicit
    round index, coverage of every nonempty community when k >= C."""
    rng = np.random.RandomState(0)
    n, n_comm = 500, 8
    comm = rng.randint(0, n_comm, n)
    infos = {i: ClientInfo(i, 2**33, 1e9, int(rng.randint(16, 64)),
                           float(rng.rand())) for i in range(n)}
    pop = ClientPopulation.from_infos(infos, community_id=comm,
                                      n_communities=n_comm)
    vs = VectorizedSelector(epsilon=0.2, seed=1)
    sel = vs.select_arrays(pop, n_comm * 2, mem_required=0, round_idx=0)
    assert len(sel) == n_comm * 2
    assert len(set(comm[sel])) == n_comm          # round-robin coverage
    assert len(set(sel.tolist())) == len(sel)     # no duplicate picks
    # last_seen updated for the picked rows only
    seen = np.asarray(pop.last_seen)
    assert (seen[sel] == 0).all()
    assert (np.delete(seen, sel) == -1).all()


def test_selector_seed_divergence_regression():
    """Two selectors with different seeds must actually diverge (the old
    ``seed + round`` stream made them walk each other's schedules); same
    seed must reproduce. Holds for both implementations."""
    W, ng, per = _clustered_sim(n_groups=4, per=6)
    clients = {i: ClientInfo(i, 2**33, 1e9, 10, loss_sum=1.0)
               for i in range(ng * per)}

    def picks(selector_cls, seed, rounds=6):
        s = selector_cls(epsilon=0.0, seed=seed, phi=1)
        s.fit_communities(W)
        return [s.select(clients, 3, mem_required=0, stage_time_fn=_time_fn)
                for _ in range(rounds)]

    for cls in (ParticipantSelector, VectorizedSelector):
        assert picks(cls, 0) == picks(cls, 0)           # reproducible
        assert picks(cls, 0) != picks(cls, 1), cls      # seeds diverge


def test_gumbel_exploration_diverges_and_covers():
    """epsilon>0: gumbel-top-k explores (different seeds, different picks)
    while still covering communities round-robin."""
    rng = np.random.RandomState(0)
    n, n_comm = 200, 5
    comm = rng.randint(0, n_comm, n)
    infos = {i: ClientInfo(i, 2**33, 1e9, 10, float(rng.rand()))
             for i in range(n)}

    def run(seed):
        pop = ClientPopulation.from_infos(infos, community_id=comm,
                                          n_communities=n_comm)
        vs = VectorizedSelector(epsilon=0.5, seed=seed)
        return [tuple(vs.select_arrays(pop, n_comm, mem_required=0,
                                       round_idx=r)) for r in range(4)]

    a, b = run(0), run(1)
    assert a != b
    for picks in a + b:
        assert len({comm[i] for i in picks}) == n_comm


# ---------------------------------------------------------------------------
# sketch similarity + label propagation vs the dense oracle
# ---------------------------------------------------------------------------


def _planted_histograms(n_groups=4, per=5, num_classes=16, seed=0):
    rng = np.random.RandomState(seed)
    hist = np.zeros((n_groups * per, num_classes))
    for i in range(n_groups * per):
        g = i // per
        hist[i, g * 2] = 50 + rng.randint(0, 10)
        hist[i, g * 2 + 1] = 30
    hist += rng.rand(*hist.shape)
    return hist


def test_sketch_similarity_approximates_exact_cosine():
    hist = _planted_histograms()
    proj = sketch_projection(hist.shape[1], 128, seed=0)
    sk = np.asarray(label_sketches(hist, proj))
    h = hist / hist.sum(1, keepdims=True)
    exact = h @ h.T
    exact /= (np.linalg.norm(h, axis=1)[:, None] * np.linalg.norm(h, axis=1))
    approx = sk @ sk.T
    approx /= np.maximum(np.linalg.norm(sk, axis=1)[:, None]
                         * np.linalg.norm(sk, axis=1), 1e-12)
    # absolute distortion is bounded (sparse histograms concentrate slowly)
    assert np.abs(exact - approx).max() < 0.4
    # ...but the structure that drives community detection — a wide gap
    # between in-group and cross-group similarity — survives sketching
    per = 5
    grp = np.arange(len(hist)) // per
    in_group = approx[(grp[:, None] == grp) & ~np.eye(len(hist), dtype=bool)]
    cross = approx[grp[:, None] != grp]
    assert in_group.min() > cross.max() + 0.3


def test_label_propagation_matches_louvain_on_planted_graph():
    hist = _planted_histograms()
    labels, n_comm = sketch_communities(hist, sketch_dim=128,
                                        num_neighbors=4, seed=0)
    W = similarity_matrix({i: hist[i] for i in range(len(hist))})
    oracle = louvain(np.maximum(W, 0))
    got = [sorted(np.flatnonzero(labels == c).tolist())
           for c in range(n_comm)]
    assert sorted(got) == sorted(sorted(c) for c in oracle)


def test_label_propagation_respects_separation():
    """Two groups sharing one class must NOT merge; near-identical
    distributions must not fragment."""
    rng = np.random.RandomState(3)
    n = 60
    hist = np.zeros((n, 8))
    grp = np.arange(n) // 30
    for i in range(n):
        hist[i, 0] = 30                        # shared class
        hist[i, 1 + grp[i] * 2] = 60 + rng.randint(0, 10)
    labels, n_comm = sketch_communities(hist, sketch_dim=64, num_neighbors=6,
                                        seed=0)
    assert n_comm == 2
    for g in (0, 1):
        assert len(set(labels[grp == g])) == 1


def test_topm_neighbors_tiling_matches_single_block():
    rng = np.random.RandomState(0)
    vecs = rng.randn(50, 16).astype(np.float32)
    nb1, w1 = topm_neighbors(vecs, 5, block_rows=50)
    nb2, w2 = topm_neighbors(vecs, 5, block_rows=7)
    np.testing.assert_array_equal(np.asarray(nb1), np.asarray(nb2))
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-6)


def test_vectorized_selector_drives_smartfreeze_server():
    """VectorizedSelector is a drop-in for the server's selection duck type."""
    import jax
    from repro.data.partition import dirichlet_partition
    from repro.data.synthetic import SyntheticVision
    from repro.fl.client import make_client_fleet
    from repro.fl.server import SmartFreezeServer
    from repro.models.cnn import CNN, CNNConfig

    sv = SyntheticVision(num_classes=4, image_size=8)
    train = sv.sample(256, seed=1)
    parts = dirichlet_partition(train["y"], 8, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    cfg = CNNConfig("rn", "resnet", stage_sizes=(1,), stage_channels=(8,),
                    num_classes=4)
    model = CNN(cfg)
    params, state = model.init(jax.random.PRNGKey(0))
    srv = SmartFreezeServer(model, clients, clients_per_round=4, batch_size=16,
                            rounds_per_stage=2, fused=False,
                            selector=VectorizedSelector(seed=0, phi=1),
                            pace_kwargs=dict(min_rounds=999))
    out = srv.run(params, state, total_rounds=2)
    assert out["rounds"] == 2
    assert all(len(r.selected) == 4 for r in out["history"])
