"""Sharding rules + memory/time models."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.memory_model import (arch_active_param_count, arch_param_count,
                                     full_model_flops, model_flops_6nd,
                                     stage_flops, stage_memory_bytes,
                                     full_model_memory_bytes)
from repro.core.time_model import stage_speedup
from repro.dist.sharding import logical_to_spec, make_rules


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_rules_llama_heads_sharded():
    mesh = FakeMesh({"data": 16, "model": 16})
    r = make_rules(configs.get("llama3-8b"), mesh)
    assert r["heads"] == "model" and r["qkv_in"] is None
    assert r["vocab"] == "model"


def test_rules_minicpm3_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    r = make_rules(configs.get("minicpm3-4b"), mesh)
    assert r["heads"] is None and r["qkv_in"] == "model"  # 40 heads % 16 != 0
    assert r["vocab"] is None and r["embed"] == "model"  # 73448 % 16 != 0


def test_rules_moe_sharding_modes():
    mesh = FakeMesh({"data": 16, "model": 16})
    r_ds = make_rules(configs.get("deepseek-v2-236b"), mesh)
    assert r_ds["expert"] == "model"  # EP: 160 / 16
    r_gk = make_rules(configs.get("grok-1-314b"), mesh)
    assert r_gk["expert"] is None and r_gk["moe_ff"] == "model"  # expert-TP


def test_logical_to_spec_no_axis_reuse():
    rules = {"a": "model", "b": "model"}
    spec = logical_to_spec(("a", "b"), rules, (32, 32))
    assert spec == P("model", None)  # axis used once


def test_param_counts_match_public_numbers():
    # within 10% of the published total param counts
    expect = {"llama3-8b": 8.0e9, "qwen2-72b": 72e9, "deepseek-v2-236b": 236e9,
              "grok-1-314b": 314e9, "deepseek-coder-33b": 33e9}
    for name, n in expect.items():
        got = arch_param_count(configs.get(name))
        assert abs(got - n) / n < 0.12, (name, got, n)


def test_moe_active_params():
    cfg = configs.get("deepseek-v2-236b")
    active = arch_active_param_count(cfg)
    total = arch_param_count(cfg)
    assert active < 0.2 * total  # ~21B active of 236B
    assert abs(active - 21e9) / 21e9 < 0.3


def test_stage_memory_reduction_magnitude():
    """Paper claims up to 82% average memory reduction — early stages of a
    deep model should show large savings vs full training."""
    cfg = configs.get("llama3-8b")
    full = full_model_memory_bytes(cfg, batch=8, seq=4096)["total"]
    st0 = stage_memory_bytes(cfg, 0, batch=8, seq=4096)["total"]
    assert st0 < 0.5 * full


def test_stage_flops_speedup():
    cfg = configs.get("llama3-8b")
    sp = stage_speedup(cfg, 0, batch=1, seq=4096)
    assert sp > 1.5  # early-stage rounds much cheaper than full training


def test_model_flops_6nd():
    cfg = configs.get("llama3-8b")
    mf = model_flops_6nd(cfg, 256, 4096)
    assert abs(mf - 6 * arch_param_count(cfg) * 256 * 4096) < 1e-3 * mf
