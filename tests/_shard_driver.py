"""Multi-device shard checks, run by tests/test_shard.py in a subprocess.

Forced host devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
must be installed BEFORE jax imports, which a normal pytest process — whose
other tests already initialized the single-device backend — cannot do. The
test module launches this script with the flag set and asserts on the JSON
report printed to stdout.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import freezing_cnn as fz
from repro.core.selector import (ClientInfo, ClientPopulation,
                                 VectorizedSelector)
from repro.core.selector.vectorized import assign_cache_tiers
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticVision
from repro.fl.client import make_client_fleet
from repro.fl.engine import RoundEngine
from repro.fl.server import SmartFreezeServer
from repro.launch.mesh import make_client_mesh
from repro.models.cnn import CNN, CNNConfig
from repro.optim import sgd

TINY = CNNConfig("tiny_resnet", "resnet", stage_sizes=(1, 1),
                 stage_channels=(8, 16), num_classes=4)


def tree_close(a, b, rtol=3e-4, atol=3e-4):
    return bool(all(
        np.allclose(np.asarray(x, np.float32), np.asarray(y, np.float32),
                    rtol=rtol, atol=atol)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))))


def main():
    report = {"n_devices": len(jax.devices())}
    mesh = make_client_mesh(8)
    sv = SyntheticVision(num_classes=4, image_size=16, seed=0)
    train = sv.sample(600, seed=1)
    parts = dirichlet_partition(train["y"], 8, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    by_id = {c.client_id: c for c in clients}
    sel = sorted(by_id)
    model = CNN(TINY)
    params, state = model.init(jax.random.PRNGKey(0))

    def engine(mesh, stage=0, **kw):
        frozen, active = fz.init_cnn_stage_active(model, params, stage,
                                                  jax.random.PRNGKey(1))
        cached = feat = None
        if stage > 0:
            cached = fz.cnn_cached_stage_loss_fn(model, stage)
            feat = lambda x: fz.cnn_prefix_features(model, frozen, state, x,
                                                    stage)
        return RoundEngine(loss_fn=fz.cnn_stage_loss_fn(model, stage),
                           optimizer=sgd(0.05), frozen=frozen,
                           cached_loss_fn=cached, feature_fn=feat,
                           batch_size=32, local_epochs=1, mesh=mesh,
                           **kw), active

    # --- 8-way fused round == single-device (params, state, losses) ---
    e0, active = engine(None)
    e1, _ = engine(mesh)
    a0, s0, l0 = e0.run_round(by_id, sel, active, state, 3)
    a1, s1, l1 = e1.run_round(by_id, sel, active, state, 3)
    report["round_params_allclose"] = tree_close(a0, a1)
    report["round_state_allclose"] = tree_close(s0, s1)
    report["round_losses_allclose"] = bool(
        all(abs(l0[c] - l1[c]) < 1e-3 for c in sel))
    report["round_uplink_equal"] = (e0.last_uplink_bytes
                                    == e1.last_uplink_bytes)

    # --- update screening on the mesh: defenses armed + zero faults must be
    # BIT-identical to the undefended sharded round (ISSUE 7 acceptance), and
    # an injected nan update must be screened out with a finite aggregate that
    # matches the single-device defended round ---
    def tree_bytes(t):
        return b"".join(np.asarray(x).tobytes() for x in jax.tree.leaves(t))

    e0, active = engine(mesh)
    e1, _ = engine(mesh, screen=True)
    a0, s0, l0 = e0.run_round(by_id, sel, active, state, 3)
    a1, s1, l1 = e1.run_round(by_id, sel, active, state, 3)
    report["screened_zero_fault_bitwise"] = (tree_bytes(a0) == tree_bytes(a1)
                                             and tree_bytes(s0) == tree_bytes(s1)
                                             and l0 == l1)
    ef, _ = engine(mesh, screen=True)
    af, sf, lf = ef.run_round(by_id, sel, active, state, 3,
                              faults={sel[0]: "nan"})
    e2, _ = engine(None, screen=True)
    a2, s2, l2 = e2.run_round(by_id, sel, active, state, 3,
                              faults={sel[0]: "nan"})
    report["screened_fault_finite"] = bool(all(
        np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(af)))
    report["screened_fault_matches_single"] = tree_close(af, a2)
    report["screened_fault_flagged"] = (ef.last_screened[sel[0]] is True
                                        and e2.last_screened[sel[0]] is True)

    # --- cohort smaller than the mesh: padding must not perturb Eq. 1 ---
    e0, active = engine(None)
    e1, _ = engine(mesh)
    a0, s0, l0 = e0.run_round(by_id, sel[:3], active, state, 5)
    a1, s1, l1 = e1.run_round(by_id, sel[:3], active, state, 5)
    report["pad_params_allclose"] = tree_close(a0, a1)
    report["pad_losses_allclose"] = bool(
        all(abs(l0[c] - l1[c]) < 1e-3 for c in sel[:3]))

    # --- tiered cache gathers under shard_map (int8 dequant in-graph) ---
    e0, active1 = engine(None, stage=1)
    e1, _ = engine(mesh, stage=1)
    cache = {cid: "int8" for cid in sel}
    a0, s0, _ = e0.run_round(by_id, sel, active1, state, 2, use_cache=cache)
    a1, s1, _ = e1.run_round(by_id, sel, active1, state, 2, use_cache=cache)
    report["tiered_cache_allclose"] = tree_close(a0, a1)

    # --- mixed tier groups: each sub-cohort pads separately and the group
    # aggregates (mesh-replicated) combine through weighted_avg ---
    e0, active1 = engine(None, stage=1)
    e1, _ = engine(mesh, stage=1)
    mixed = {cid: ("int8" if i % 2 else None) for i, cid in enumerate(sel)}
    a0, s0, _ = e0.run_round(by_id, sel, active1, state, 4, use_cache=mixed)
    a1, s1, _ = e1.run_round(by_id, sel, active1, state, 4, use_cache=mixed)
    report["mixed_groups_allclose"] = tree_close(a0, a1)

    # --- compressed rounds: psum of sparse partial aggregates + EF carry ---
    e0, active = engine(None, compress_ratio=0.3)
    e1, _ = engine(mesh, compress_ratio=0.3)
    p0 = e0.run_round(by_id, sel, active, state, 0)
    p1 = e1.run_round(by_id, sel, active, state, 0)
    q0 = e0.run_round(by_id, sel, p0[0], p0[1], 1)
    q1 = e1.run_round(by_id, sel, p1[0], p1[1], 1)
    report["compressed_allclose"] = (tree_close(p0[0], p1[0])
                                     and tree_close(q0[0], q1[0], rtol=5e-4,
                                                    atol=5e-4))
    report["compressed_uplink_equal"] = (e0.last_uplink_bytes
                                         == e1.last_uplink_bytes)

    # --- full SmartFreeze server: picks / losses / uplink / params ---
    def run_server(mesh):
        srv = SmartFreezeServer(model, clients, clients_per_round=4,
                                batch_size=32, rounds_per_stage=2, seed=0,
                                mesh=mesh, cache_tiers="all",
                                pace_kwargs=dict(min_rounds=99))
        out = srv.run(params, state, schedule=[2, 2])
        return out, srv

    out0, srv0 = run_server(None)
    out1, srv1 = run_server(mesh)
    report["server_picks_equal"] = ([r.selected for r in srv0.history]
                                    == [r.selected for r in srv1.history])
    report["server_uplink_equal"] = (
        [r.uplink_bytes for r in srv0.history]
        == [r.uplink_bytes for r in srv1.history])
    report["server_losses_allclose"] = bool(np.allclose(
        [r.loss for r in srv0.history], [r.loss for r in srv1.history],
        rtol=1e-4, atol=1e-4))
    report["server_params_allclose"] = tree_close(out0["params"],
                                                  out1["params"])
    report["server_vtime_equal"] = (out0["virtual_time"]
                                    == out1["virtual_time"])

    # --- sharded population: selection picks + cache-tier admission ---
    rng = np.random.RandomState(0)
    n = 64
    infos = {i: ClientInfo(i, float(rng.choice([1, 2, 4, 8])) * 2**30,
                           float(rng.choice([1e9, 5e9])),
                           int(rng.randint(32, 512)), float(rng.rand()))
             for i in range(n)}
    comm = rng.randint(0, 4, size=n)
    pop = ClientPopulation.from_infos(infos, community_id=comm,
                                      n_communities=4)
    pop_s = pop.shard(mesh)
    vs = VectorizedSelector(epsilon=0.2, seed=3)
    picks = vs.select_arrays(pop, 16, mem_required=1.5 * 2**30, round_idx=5)
    picks_s = vs.select_arrays(pop_s, 16, mem_required=1.5 * 2**30,
                               round_idx=5)
    report["population_picks_equal"] = bool(np.array_equal(picks, picks_s))
    rates = [4e3, 2e3, 1e3]
    report["admission_equal"] = bool(np.array_equal(
        assign_cache_tiers(pop, 1e8, rates),
        assign_cache_tiers(pop_s, 1e8, rates)))

    # --- N not divisible by the device count: replicated fallback ---
    pop61 = ClientPopulation.from_infos({i: infos[i] for i in range(61)})
    p61 = pop61.shard(mesh)
    report["nondiv_replicated"] = bool(
        p61.memory_bytes.sharding.is_fully_replicated)
    report["nondiv_admission_equal"] = bool(np.array_equal(
        assign_cache_tiers(pop61, 1e8, rates),
        assign_cache_tiers(p61, 1e8, rates)))

    print("JSON:" + json.dumps(report))


if __name__ == "__main__":
    main()
