"""Optimizers + schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw, apply_updates, clip_by_global_norm, constant,
                         cosine, global_norm, momentum, sgd, warmup_cosine)


def _minimize(opt, steps=200):
    params = {"w": jnp.asarray([3.0, -2.0], jnp.float32)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(steps):
        g = jax.grad(loss)(params)
        ups, state = opt.update(g, state, params)
        params = apply_updates(params, ups)
    return float(loss(params))


def test_sgd_converges():
    assert _minimize(sgd(0.1)) < 1e-4


def test_momentum_converges():
    assert _minimize(momentum(0.02, 0.9)) < 1e-4


def test_adamw_converges():
    assert _minimize(adamw(0.05)) < 1e-3


def test_adamw_weight_decay_shrinks():
    opt = adamw(0.01, weight_decay=0.5)
    params = {"w": jnp.asarray([5.0], jnp.float32)}
    state = opt.init(params)
    zeros = {"w": jnp.asarray([0.0], jnp.float32)}
    for _ in range(100):
        ups, state = opt.update(zeros, state, params)
        params = apply_updates(params, ups)
    assert abs(float(params["w"][0])) < 5.0 * 0.7


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_schedules_monotone_pieces():
    s = warmup_cosine(1.0, 10, 100)
    vals = [float(s(jnp.int32(t))) for t in range(0, 100, 5)]
    assert vals[0] < vals[1]  # warmup rises
    assert vals[-1] < vals[3]  # cosine decays
    assert float(cosine(1.0, 100)(jnp.int32(0))) == 1.0
    assert float(constant(0.3)(jnp.int32(50))) == np.float32(0.3)


def test_bf16_params_update_in_fp32():
    opt = adamw(0.1)
    params = {"w": jnp.asarray([1.0], jnp.bfloat16)}
    state = opt.init(params)
    g = {"w": jnp.asarray([0.001], jnp.bfloat16)}
    ups, state = opt.update(g, state, params)
    new = apply_updates(params, ups)
    assert new["w"].dtype == jnp.bfloat16
    assert float(new["w"][0]) != 1.0  # tiny update not lost before cast
