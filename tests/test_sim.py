"""Virtual-time simulation core: trajectory identity of the refactored
servers vs a hand-rolled seed-style loop, deadline partial aggregation ==
hand-masked Eq. 1, sync permutation invariance, async-buffered staleness
math, virtual-clock ordering, and bit-identical checkpoint/resume."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import freezing_cnn as fz
from repro.core.pace import PaceController
from repro.core.selector import ParticipantSelector
from repro.core.time_model import cohort_round_time, round_time
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticVision
from repro.fl.client import make_client_fleet
from repro.fl.engine import RoundEngine, weighted_avg
from repro.fl.server import FedAvgServer, SmartFreezeServer
from repro.fl.sim import (AsyncBufferedAggregation, AvailabilityTrace,
                          DeadlineAggregation, FederatedLoop, FleetTimeModel,
                          SyncAggregation, pack_rng_state, unpack_rng_state)
from repro.models.cnn import CNN, CNNConfig
from repro.optim import sgd

TINY = CNNConfig("tiny_resnet", "resnet", stage_sizes=(1, 1),
                 stage_channels=(8, 16), num_classes=4)


@pytest.fixture(scope="module")
def world():
    sv = SyntheticVision(num_classes=4, image_size=16, seed=0)
    train = sv.sample(720, seed=1)
    parts = dirichlet_partition(train["y"], 8, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    model = CNN(TINY)
    params, state = model.init(jax.random.PRNGKey(0))
    return train, clients, model, params, state


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# satellite: empty-cohort round time
# ---------------------------------------------------------------------------


def test_round_time_empty_cohort_is_zero():
    from repro import configs
    cfg = configs.get("llama3-8b").reduced(num_layers=2)
    assert round_time(cfg, 0, []) == 0.0          # no ValueError
    assert cohort_round_time([]) == 0.0
    assert round_time(cfg, 0, [{"num_samples": 10, "capability": 1e9}]) > 0.0


# ---------------------------------------------------------------------------
# time model + traces
# ---------------------------------------------------------------------------


def test_fleet_time_model_matches_seed_heuristic(world):
    _, clients, *_ = world
    tm = FleetTimeModel.from_clients(clients)
    t = tm.cohort_times([c.client_id for c in clients], 0)
    for c in clients:
        np.testing.assert_allclose(t[c.client_id],
                                   c.num_samples / c.capability, rtol=1e-5)


def test_time_model_links_and_jitter_deterministic(world):
    _, clients, *_ = world
    tm = FleetTimeModel.from_clients(clients, link_rates=[1e6] * len(clients),
                                     jitter=0.3, seed=3)
    tm.payload_bytes = 2e6
    a = tm.cohort_times([0, 1, 2], 7)
    b = tm.cohort_times([2, 1, 0], 7)     # order-independent
    assert a == {k: b[k] for k in a}
    assert a[0] >= 2.0  # 2 MB at 1 MB/s uplink dominates
    jtm = FleetTimeModel.from_clients(clients, jitter=0.3, seed=3)
    j7, j8 = jtm.cohort_times([0], 7)[0], jtm.cohort_times([0], 8)[0]
    assert j7 != j8                       # jitter varies per round
    assert jtm.cohort_times([0], 7)[0] == j7  # ... but replays exactly
    base = FleetTimeModel.from_clients(clients)
    assert base.cohort_times([0], 7)[0] > 0


def test_availability_trace_replayable():
    tr = AvailabilityTrace(p_available=0.5, p_dropout=0.3, seed=5)
    ids = list(range(40))
    assert tr.available(ids, 3) == tr.available(ids, 3)
    assert tr.dropouts(ids, 3) == tr.dropouts(ids, 3)
    assert tr.available(ids, 3) != tr.available(ids, 4)
    assert 0 < len(tr.available(ids, 3)) < 40


# ---------------------------------------------------------------------------
# trajectory identity: FederatedLoop-based servers == seed-style loops
# ---------------------------------------------------------------------------


def test_fedavg_trajectory_identical_to_seed_loop(world):
    """The refactored FedAvgServer must reproduce the seed's hand-rolled
    loop (selection RNG stream + engine rounds) exactly."""
    _, clients, model, params, state = world
    srv = FedAvgServer(model, clients, clients_per_round=4, batch_size=32,
                       seed=3, fused=False)
    out = srv.run(params, state, rounds=3)

    # seed-style reference loop (pre-refactor algorithm, verbatim)
    def full_loss(p, frozen_unused, st, batch):
        return model.loss(p, st, batch, train=True)

    engine = RoundEngine(loss_fn=full_loss, optimizer=sgd(0.05),
                         batch_size=32, local_epochs=1, clip_norm=10.0,
                         fused=False)
    rng = np.random.RandomState(3)
    by_id = {c.client_id: c for c in clients}
    eligible = list(by_id)
    p_ref, s_ref = params, state
    for r in range(3):
        sel = list(rng.choice(eligible, size=4, replace=False))
        assert [int(c) for c in sel] == [int(c) for c in out["history"][r].selected]
        p_ref, s_ref, losses = engine.run_round(by_id, sel, p_ref, s_ref, r)
        np.testing.assert_allclose(out["history"][r].loss,
                                   float(np.mean(list(losses.values()))),
                                   rtol=0, atol=0)
    _tree_equal(out["params"], p_ref)
    _tree_equal(out["state"], s_ref)


def test_smartfreeze_selection_series_identical_to_seed_selector(world):
    """SmartFreeze's per-round picks must match replaying the selector with
    the same info stream (the loop changes orchestration, not policy)."""
    _, clients, model, params, state = world
    srv = SmartFreezeServer(model, clients, clients_per_round=3,
                            rounds_per_stage=2, seed=1, fused=False,
                            pace_kwargs=dict(min_rounds=99))
    out = srv.run(params, state, total_rounds=4)
    assert out["rounds"] == 4
    # replay: fresh selector, same similarity -> same communities and picks
    srv2 = SmartFreezeServer(model, clients, clients_per_round=3,
                             rounds_per_stage=2, seed=1, fused=False,
                             pace_kwargs=dict(min_rounds=99))
    out2 = srv2.run(params, state, total_rounds=4)
    for a, b in zip(out["history"], out2["history"]):
        assert a.selected == b.selected
        assert a.loss == b.loss
        assert a.virtual_time == b.virtual_time
    _tree_equal(out["params"], out2["params"])


def test_sync_duration_is_slowest_survivor(world):
    _, clients, model, params, state = world
    srv = FedAvgServer(model, clients, clients_per_round=4, batch_size=32,
                       seed=0, fused=False)
    out = srv.run(params, state, rounds=2)
    tm = FleetTimeModel.from_clients(clients)
    for rr in out["history"]:
        times = tm.cohort_times(rr.selected, rr.round_idx)
        # payload_bytes was set by the server, recompute with it
        assert rr.duration == pytest.approx(
            max(times.values()), rel=1e-5)
        assert rr.virtual_time >= rr.duration
    assert out["virtual_time"] == pytest.approx(
        sum(r.duration for r in out["history"]), rel=1e-6)


# ---------------------------------------------------------------------------
# deadline policy: partial aggregation == hand-masked Eq. 1 (fused=False)
# ---------------------------------------------------------------------------


def test_deadline_partial_agg_equals_hand_masked_eq1(world):
    """One deadline round on the sequential path must equal Eq. 1 computed
    by hand over exactly the finishing cohort."""
    _, clients, model, params, state = world
    by_id = {c.client_id: c for c in clients}
    caps = [c.capability for c in clients]
    # heavy-tailed: clients 0,1 are 100x slower than the rest
    for c in clients:
        c.capability = 1e7 if c.client_id in (0, 1) else 1e9

    srv = FedAvgServer(model, clients, clients_per_round=8, batch_size=32,
                       seed=0, fused=False,
                       aggregation=DeadlineAggregation(factor=2.0))
    out = srv.run(params, state, rounds=1)
    tm = FleetTimeModel.from_clients(clients)
    for c, cap in zip(clients, caps):
        c.capability = cap   # restore the shared fixture
    rr = out["history"][0]
    sel = rr.selected
    assert rr.dropped, "stragglers should have missed the deadline"
    assert all(c not in sel for c in rr.dropped)

    # hand-masked Eq. 1 over the finishing cohort only
    def full_loss(p, frozen_unused, st, batch):
        return model.loss(p, st, batch, train=True)

    engine = RoundEngine(loss_fn=full_loss, optimizer=sgd(0.05),
                         batch_size=32, local_epochs=1, clip_norm=10.0,
                         fused=False)
    updates, weights = [], []
    for cid in sel:
        p_i, s_i, _ = engine.run_round(by_id, [cid], params, state, 0,
                                       sequential=True)
        updates.append((p_i, s_i))
        weights.append(by_id[cid].num_samples)
    w = np.asarray(weights, np.float64)
    w /= w.sum()
    p_ref = weighted_avg([u[0] for u in updates], w)
    s_ref = weighted_avg([u[1] for u in updates], w)
    for a, b in zip(jax.tree.leaves(out["params"]), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=2e-5)
    for a, b in zip(jax.tree.leaves(out["state"]), jax.tree.leaves(s_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=2e-5)
    # the deadline round's virtual duration is the deadline, not the
    # straggler tail
    times = tm.cohort_times(list(sel) + list(rr.dropped), 0)
    assert rr.duration < max(times.values())


def test_sync_result_invariant_to_completion_time_permutation(world):
    """Permuting per-client completion times must not change a sync round's
    aggregate (the barrier waits for everyone; Eq. 1 is order-free)."""
    _, clients, model, params, state = world
    caps = [c.capability for c in clients]

    def run_once():
        srv = FedAvgServer(model, clients, clients_per_round=5,
                           batch_size=32, seed=2, fused=False)
        return srv.run(params, state, rounds=2)

    out_a = run_once()
    rng = np.random.RandomState(0)
    perm = rng.permutation(len(caps))
    for c, j in zip(clients, perm):
        c.capability = caps[j]
    out_b = run_once()
    for c, cap in zip(clients, caps):
        c.capability = cap   # restore for other tests
    _tree_equal(out_a["params"], out_b["params"])
    _tree_equal(out_a["state"], out_b["state"])
    for a, b in zip(out_a["history"], out_b["history"]):
        assert list(a.selected) == list(b.selected) and a.loss == b.loss


# ---------------------------------------------------------------------------
# async-buffered (FedBuff) policy
# ---------------------------------------------------------------------------


def test_async_buffered_staleness_weighted_merge(world):
    _, clients, model, params, state = world
    pol = AsyncBufferedAggregation(buffer_size=3, concurrency=6,
                                   staleness_power=0.5)
    srv = FedAvgServer(model, clients, clients_per_round=6, batch_size=32,
                       seed=0, fused=False, aggregation=pol)
    out = srv.run(params, state, rounds=4)
    assert len(out["history"]) == 4
    for rr in out["history"]:
        assert len(rr.selected) == 3          # buffer_size merges per tick
        assert np.isfinite(rr.loss)
        assert rr.duration >= 0.0
    # virtual clock is monotone and some in-flight client crossed an
    # aggregation boundary (staleness observed) across 4 ticks
    vt = [rr.virtual_time for rr in out["history"]]
    assert all(b >= a for a, b in zip(vt, vt[1:]))
    # params actually moved
    moved = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(out["params"]), jax.tree.leaves(params)))
    assert moved > 0


def test_async_staleness_weight_formula(world):
    """A one-buffer merge with known staleness must apply
    w = |D| * (1+s)^-a to the client's delta."""
    _, clients, model, params, state = world
    by_id = {c.client_id: c for c in clients}
    cid = clients[0].client_id

    def full_loss(p, frozen_unused, st, batch):
        return model.loss(p, st, batch, train=True)

    engine = RoundEngine(loss_fn=full_loss, optimizer=sgd(0.05),
                         batch_size=32, local_epochs=1, clip_norm=10.0,
                         fused=False)
    p_i, s_i, _ = engine.run_round(by_id, [cid], params, state, 0,
                                   sequential=True)
    # buffer_size=1, single client in flight -> staleness 0, w cancels out:
    # merged params == the client's own trained params
    box = {}
    loop = FederatedLoop(
        select_fn=lambda r, avail: [cid],
        train_fn=None,
        clients=by_id,
        aggregation=AsyncBufferedAggregation(buffer_size=1, concurrency=1),
        snapshot_fn=lambda: (box["p"], box["s"]),
        train_one_fn=lambda c, p, s, r: engine.run_round(
            by_id, [c], p, s, r, sequential=True)[:2] + (0.0,),
        get_model_fn=lambda: (box["p"], box["s"]),
        set_model_fn=lambda p, s: box.update(p=p, s=s))
    box["p"], box["s"] = params, state
    loop.run(1)
    for a, b in zip(jax.tree.leaves(box["p"]), jax.tree.leaves(p_i)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_absolute_deadline_applies_to_small_cohorts(world):
    """deadline_s (unlike the median-relative factor) caps ANY cohort —
    including size <= 2 — and bounds the round's virtual duration."""
    _, clients, model, params, state = world
    caps = [c.capability for c in clients]
    for c in clients:
        c.capability = 1e4 if c.client_id == 0 else 1e9
    tm = FleetTimeModel.from_clients(clients)
    slow_t = tm.cohort_times([0], 0)[0]
    fast_t = max(tm.cohort_times([1], 0).values())
    deadline = (fast_t + slow_t) / 2
    srv = FedAvgServer(model, clients, clients_per_round=2, batch_size=32,
                       seed=8, fused=False,
                       aggregation=DeadlineAggregation(deadline_s=deadline))
    out = srv.run(params, state, rounds=4)
    for c, cap in zip(clients, caps):
        c.capability = cap
    hit = [rr for rr in out["history"] if 0 in set(map(int, rr.dropped))]
    assert hit, "the slow client was never selected"
    for rr in out["history"]:
        assert rr.duration <= deadline + 1e-9
        assert 0 not in set(map(int, rr.selected))


# ---------------------------------------------------------------------------
# virtual clock: deadline beats sync on a straggler-heavy fleet
# ---------------------------------------------------------------------------


def test_deadline_virtual_time_beats_sync(world):
    _, clients, model, params, state = world
    caps = [c.capability for c in clients]
    for c in clients:
        c.capability = 2e7 if c.client_id < 2 else 1e9

    def total_time(policy):
        srv = FedAvgServer(model, clients, clients_per_round=6, batch_size=32,
                           seed=0, fused=False, aggregation=policy)
        return srv.run(params, state, rounds=3)["virtual_time"]

    t_sync = total_time("sync")
    t_dl = total_time(DeadlineAggregation(factor=2.0))
    for c, cap in zip(clients, caps):
        c.capability = cap
    assert t_dl < t_sync


# ---------------------------------------------------------------------------
# dropout: empty cohorts cost nothing, loop survives
# ---------------------------------------------------------------------------


def test_dropout_and_empty_cohort_round(world):
    _, clients, model, params, state = world
    srv = FedAvgServer(model, clients, clients_per_round=4, batch_size=32,
                       seed=0, fused=False,
                       availability=AvailabilityTrace(p_dropout=1.0, seed=0))
    out = srv.run(params, state, rounds=2)
    for rr in out["history"]:
        assert rr.selected == []
        assert rr.dropped
        assert rr.duration == 0.0          # empty cohort costs 0 virtual s
    _tree_equal(out["params"], params)     # nothing aggregated


# ---------------------------------------------------------------------------
# checkpoint/resume: bit-identical continuation across a freeze boundary
# ---------------------------------------------------------------------------


def _sf_server(model, clients, **kw):
    # slope_lambda is deliberately loose so stage 0 freezes deterministically
    # a round or two after min_rounds — the resume test needs to cross a
    # stage-freeze boundary
    return SmartFreezeServer(model, clients, clients_per_round=4,
                             batch_size=32, rounds_per_stage=5, seed=0,
                             pace_kwargs=dict(min_rounds=3, mu=2,
                                              slope_lambda=0.5), **kw)


def test_smartfreeze_resume_bit_identical(world, tmp_path):
    from repro.checkpoint import CheckpointManager
    _, clients, model, params, state = world

    srv_a = _sf_server(model, clients)
    out_a = srv_a.run(params, state)
    # a freeze must actually happen inside stage 0 for the boundary check
    frozen_rounds = [r.round_idx for r in out_a["history"] if r.frozen]
    assert frozen_rounds, "expected a pace freeze in this configuration"

    # run B: checkpoint every round, crash after round 1, resume, continue
    mgr = CheckpointManager(str(tmp_path / "ck"), async_save=False)
    srv_b = _sf_server(model, clients)
    pace_limit = {"n": 0}

    class Crash(Exception):
        pass

    def crashing_eval(p, s, stage):
        pace_limit["n"] += 1
        if pace_limit["n"] > 2:
            raise Crash()
        return 0.0

    with pytest.raises(Crash):
        srv_b.run(params, state, ckpt_manager=mgr, ckpt_every=1,
                  eval_fn=crashing_eval, eval_every=1)
    done_rounds = len(srv_b.history)
    assert 0 < done_rounds < len(out_a["history"])

    srv_c = _sf_server(model, clients)
    out_c = srv_c.run(params, state, ckpt_manager=mgr, ckpt_every=1,
                      resume=True)
    # the crashed round was never recorded; resume re-runs it
    combined = srv_b.history + out_c["history"]
    ref = out_a["history"]
    assert len(combined) == len(ref)
    for a, b in zip(ref, combined):
        assert a.round_idx == b.round_idx
        assert a.stage == b.stage
        assert a.selected == b.selected
        assert a.loss == b.loss, (a.round_idx, a.loss, b.loss)
        if a.perturbation is None:
            assert b.perturbation is None
        else:
            np.testing.assert_allclose(a.perturbation, b.perturbation,
                                       rtol=1e-12)
        assert a.frozen == b.frozen
        np.testing.assert_allclose(a.virtual_time, b.virtual_time, rtol=1e-9)
    # resumed run crossed the stage-freeze boundary into stage 1
    assert {r.stage for r in out_c["history"]} >= {1}
    _tree_equal(out_a["params"], out_c["params"])
    _tree_equal(out_a["state"], out_c["state"])


def test_fedavg_resume_matches_uninterrupted(world, tmp_path):
    from repro.checkpoint import CheckpointManager
    _, clients, model, params, state = world
    srv_a = FedAvgServer(model, clients, clients_per_round=4, batch_size=32,
                         seed=4, fused=False)
    out_a = srv_a.run(params, state, rounds=4)

    mgr = CheckpointManager(str(tmp_path / "ck"), async_save=False)
    srv_b = FedAvgServer(model, clients, clients_per_round=4, batch_size=32,
                         seed=4, fused=False)
    srv_b.run(params, state, rounds=2, ckpt_manager=mgr, ckpt_every=1)
    srv_c = FedAvgServer(model, clients, clients_per_round=4, batch_size=32,
                         seed=4, fused=False)
    out_c = srv_c.run(params, state, rounds=4, ckpt_manager=mgr,
                      resume=True)
    combined = srv_b.history + out_c["history"]
    assert len(combined) == 4
    for a, b in zip(out_a["history"], combined):
        assert a.selected == b.selected and a.loss == b.loss
    _tree_equal(out_a["params"], out_c["params"])


def test_rng_state_roundtrip():
    rs = np.random.RandomState(42)
    rs.rand(17)
    rs2 = unpack_rng_state(pack_rng_state(rs))
    np.testing.assert_array_equal(rs.rand(8), rs2.rand(8))


# ---------------------------------------------------------------------------
# pace controller serialization (used by the resume path)
# ---------------------------------------------------------------------------


def test_pace_state_roundtrip():
    rng = np.random.RandomState(0)
    a = PaceController(window_q=3, min_rounds=1)
    theta = rng.randn(40).astype(np.float32)
    for _ in range(5):
        theta = theta + rng.randn(40).astype(np.float32) * 0.1
        a.observe({"w": theta})
    b = PaceController(window_q=3, min_rounds=1)
    b.load_state_dict(a.state_dict())
    for _ in range(4):
        theta = theta + rng.randn(40).astype(np.float32) * 0.1
        pa = a.observe({"w": theta})
        pb = b.observe({"w": theta})
        assert pa == pb
    assert a.should_freeze() == b.should_freeze()


def test_smartfreeze_survives_availability_dips(world):
    """A round where too few clients are AVAILABLE is skipped (0.0 virtual
    seconds), not escalated to InfeasibleStageError — that error is reserved
    for genuine Eq. 14 memory infeasibility."""
    _, clients, model, params, state = world
    srv = SmartFreezeServer(model, clients, clients_per_round=3,
                            rounds_per_stage=2, seed=1, fused=False,
                            pace_kwargs=dict(min_rounds=99),
                            availability=AvailabilityTrace(p_available=0.15,
                                                           seed=2))
    out = srv.run(params, state, total_rounds=4)
    assert len(out["history"]) == 4
    skipped = [r for r in out["history"] if not r.selected]
    assert skipped, "p=0.15 on 8 clients should starve at least one round"
    for r in skipped:
        assert r.duration == 0.0
