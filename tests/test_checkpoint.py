"""Checkpointing: atomic roundtrip, retention, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"a": jnp.asarray(rng.randn(8, 4), jnp.float32),
            "b": {"c": jnp.asarray(rng.randn(3), jnp.bfloat16),
                  "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t, metadata={"stage": 2})
    out = restore_checkpoint(str(tmp_path))
    assert out["step"] == 5 and out["metadata"]["stage"] == 2
    np.testing.assert_array_equal(out["tree"]["a"], np.asarray(t["a"]))
    restored_c = np.asarray(out["tree"]["b"]["c"], dtype=np.float32)
    np.testing.assert_array_equal(restored_c,
                                  np.asarray(t["b"]["c"], dtype=np.float32))
    assert str(out["tree"]["b"]["c"].dtype) == "bfloat16"


def test_uncommitted_checkpoint_ignored(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    save_checkpoint(str(tmp_path), 2, _tree(1))
    os.remove(str(tmp_path / "step_2.COMMIT"))  # simulated crash mid-commit
    assert latest_step(str(tmp_path)) == 1
    assert restore_checkpoint(str(tmp_path))["step"] == 1


def test_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in range(5):
        mgr.save(s, _tree(s))
    mgr.wait()
    mgr._gc()
    kept = sorted(mgr._committed())
    assert kept == [3, 4]
    out = mgr.restore()
    assert out["step"] == 4


def test_elastic_restore_resharding(tmp_path):
    """Restore onto explicit shardings (mesh may differ between runs)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    save_checkpoint(str(tmp_path), 0, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"a": NamedSharding(mesh, P("data")),
          "b": {"c": NamedSharding(mesh, P()),
                "step": NamedSharding(mesh, P())}}
    out = restore_checkpoint(str(tmp_path), shardings=sh)
    assert out["tree"]["a"].sharding.spec == P("data")
    np.testing.assert_array_equal(np.asarray(out["tree"]["a"]),
                                  np.asarray(t["a"]))


def test_resume_training_state(tmp_path):
    """Full train-state resume: params + opt state + step counter."""
    from repro import configs
    from repro.core import freezing
    from repro.data.synthetic import make_lm_batch
    from repro.models.transformer import build
    from repro.optim import adamw

    cfg = configs.get("llama3-8b").reduced(num_layers=4, num_freeze_blocks=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = freezing.make_stage_plan(cfg, 0)
    frozen, active = freezing.init_stage_active(model, params, plan,
                                                jax.random.PRNGKey(1))
    opt = adamw(1e-3)
    step = jax.jit(freezing.make_train_step(model, plan, opt, remat=False))
    state = freezing.TrainState(active, frozen, opt.init(active), jnp.int32(0))
    batch = {k: jnp.asarray(v) for k, v in make_lm_batch(cfg, 2, 16).items()}
    state, _ = step(state, batch)
    save_checkpoint(str(tmp_path), 1, {"active": state.active,
                                       "opt": state.opt_state})
    restored = restore_checkpoint(str(tmp_path))["tree"]
    state2 = freezing.TrainState(
        jax.tree.map(lambda a, b: jnp.asarray(b, a.dtype), state.active,
                     restored["active"]),
        frozen,
        jax.tree.map(lambda a, b: jnp.asarray(b, a.dtype), state.opt_state,
                     restored["opt"]),
        jnp.int32(1))
    s_a, m_a = step(state, batch)
    s_b, m_b = step(state2, batch)
    np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]), rtol=1e-5)
