"""Round-engine invariants: cached-feature training == full recompute on
both freezing backends, fused vmapped rounds == the sequential per-client
loop for fixed seeds, and the memory-model cache hook gates who caches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import freezing
from repro.core import freezing_cnn as fz
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticVision, make_lm_batch
from repro.fl.client import make_client_fleet
from repro.fl.engine import RoundEngine, make_lm_cached_fed_round_step
from repro.fl.server import SmartFreezeServer, cnn_stage_memory_bytes
from repro.models.cnn import CNN, CNNConfig
from repro.models.transformer import build
from repro.optim import sgd

TINY = CNNConfig("tiny_resnet", "resnet", stage_sizes=(1, 1),
                 stage_channels=(8, 16), num_classes=4)
LM_CFG = configs.get("llama3-8b").reduced(num_layers=4, num_freeze_blocks=2)


def _cnn_world(n_clients=6, n=600):
    sv = SyntheticVision(num_classes=4, image_size=16, seed=0)
    train = sv.sample(n, seed=1)
    parts = dirichlet_partition(train["y"], n_clients, alpha=1.0, seed=0)
    clients = make_client_fleet(train, parts, scenario="low", seed=0)
    model = CNN(TINY)
    params, state = model.init(jax.random.PRNGKey(0))
    return train, clients, model, params, state


def _tree_allclose(a, b, rtol=2e-4, atol=2e-4):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# cached features vs full recompute: logits equivalence
# ---------------------------------------------------------------------------


def test_cnn_cached_logits_match_recompute():
    train, clients, model, params, state = _cnn_world()
    stage = 1
    frozen, active = fz.init_cnn_stage_active(model, params, stage,
                                              jax.random.PRNGKey(1))
    x = jnp.asarray(train["x"][:32])
    full = jax.jit(lambda a, f, s, xx: fz.cnn_stage_forward(
        model, f, a, s, xx, stage))
    feats = jax.jit(lambda f, s, xx: fz.cnn_prefix_features(
        model, f, s, xx, stage))(frozen, state, x)
    cached = jax.jit(lambda a, s, h: fz.cnn_stage_forward_from_features(
        model, a, s, h, stage))
    l_full, _ = full(active, frozen, state, x)
    l_cached, _ = cached(active, state, feats)
    np.testing.assert_allclose(np.asarray(l_cached, np.float32),
                               np.asarray(l_full, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_lm_cached_logits_match_recompute():
    model = build(LM_CFG)
    params = model.init(jax.random.PRNGKey(0))
    plan = freezing.make_stage_plan(LM_CFG, 1)
    assert freezing.prefix_is_static(plan)
    frozen, active = freezing.init_stage_active(model, params, plan,
                                                jax.random.PRNGKey(1))
    batch = {k: jnp.asarray(v) for k, v in make_lm_batch(LM_CFG, 2, 32).items()}

    def full_logits(a, f, b):
        h, w, _ = freezing.stage_forward(model, f, a, b, plan, remat=False)
        return h @ w.astype(h.dtype)

    def cached_logits(a, h0, aux0):
        h, w, _ = freezing.stage_forward_from_features(model, a, h0, aux0,
                                                       plan, remat=False)
        return h @ w.astype(h.dtype)

    h0, aux0 = jax.jit(lambda f, a, b: freezing.stage_prefix_features(
        model, f, a, b, plan))(frozen, active, batch)
    lf = jax.jit(full_logits)(active, frozen, batch)
    lc = jax.jit(cached_logits)(active, h0, aux0)
    np.testing.assert_allclose(np.asarray(lc, np.float32),
                               np.asarray(lf, np.float32),
                               rtol=2e-2, atol=2e-2)  # bf16 compute


def test_lm_cached_round_rejects_non_static_prefix():
    import pytest

    model = build(LM_CFG)
    plan0 = freezing.make_stage_plan(LM_CFG, 0)  # embedding trains
    with pytest.raises(ValueError, match="not a fixed feature extractor"):
        make_lm_cached_fed_round_step(model, plan0, sgd(0.05),
                                      num_pods=1, local_steps=1)


def test_prefix_static_detection():
    # stage 0 trains the embedding: features move every step
    assert not freezing.prefix_is_static(freezing.make_stage_plan(LM_CFG, 0))
    assert freezing.prefix_is_static(freezing.make_stage_plan(LM_CFG, 1))
    # zamba2: weight-tied shared attention in the prefix keeps training
    zcfg = configs.get("zamba2-7b").reduced(num_layers=4, num_freeze_blocks=2)
    zplan = freezing.make_stage_plan(zcfg, 1)
    assert any(k == "shared_attn" for _, k, *_ in zplan.runs)
    assert not freezing.prefix_is_static(zplan)


# ---------------------------------------------------------------------------
# fused vmapped round vs sequential per-client loop
# ---------------------------------------------------------------------------


def _stage_engine(model, stage, frozen, state, *, fused):
    cached_loss = feature_fn = None
    if stage > 0:
        cached_loss = fz.cnn_cached_stage_loss_fn(model, stage)
        feature_fn = lambda x: fz.cnn_prefix_features(model, frozen, state, x,
                                                      stage)
    return RoundEngine(loss_fn=fz.cnn_stage_loss_fn(model, stage),
                       optimizer=sgd(0.05), frozen=frozen,
                       cached_loss_fn=cached_loss, feature_fn=feature_fn,
                       batch_size=32, local_epochs=1, fused=fused)


def test_fused_round_matches_sequential():
    train, clients, model, params, state = _cnn_world()
    by_id = {c.client_id: c for c in clients}
    stage = 0
    frozen, active = fz.init_cnn_stage_active(model, params, stage,
                                              jax.random.PRNGKey(1))
    sel = [c.client_id for c in clients[:4]]  # unequal shard sizes
    a_f, s_f, l_f = _stage_engine(model, stage, frozen, state, fused=True) \
        .run_round(by_id, sel, active, state, 3)
    a_s, s_s, l_s = _stage_engine(model, stage, frozen, state, fused=False) \
        .run_round(by_id, sel, active, state, 3)
    _tree_allclose(a_f, a_s)
    _tree_allclose(s_f, s_s)
    for cid in sel:
        assert abs(l_f[cid] - l_s[cid]) < 1e-3, (cid, l_f[cid], l_s[cid])


def test_cached_round_matches_recompute_round():
    train, clients, model, params, state = _cnn_world()
    by_id = {c.client_id: c for c in clients}
    stage = 1
    frozen, active = fz.init_cnn_stage_active(model, params, stage,
                                              jax.random.PRNGKey(1))
    sel = [c.client_id for c in clients[:4]]
    eng = lambda: _stage_engine(model, stage, frozen, state, fused=True)
    a_r, s_r, _ = eng().run_round(by_id, sel, active, state, 0, use_cache={})
    a_c, s_c, _ = eng().run_round(by_id, sel, active, state, 0,
                                  use_cache={cid: True for cid in sel})
    _tree_allclose(a_c, a_r)
    _tree_allclose(s_c, s_r)


def test_mixed_cache_cohort_matches_uniform():
    """Half the cohort on cached features, half on recompute — the grouped
    aggregation must equal the flat-cohort result."""
    train, clients, model, params, state = _cnn_world()
    by_id = {c.client_id: c for c in clients}
    stage = 1
    frozen, active = fz.init_cnn_stage_active(model, params, stage,
                                              jax.random.PRNGKey(1))
    sel = [c.client_id for c in clients[:4]]
    eng = lambda: _stage_engine(model, stage, frozen, state, fused=True)
    a_u, s_u, _ = eng().run_round(by_id, sel, active, state, 0, use_cache={})
    a_m, s_m, _ = eng().run_round(by_id, sel, active, state, 0,
                                  use_cache={sel[0]: True, sel[2]: True})
    _tree_allclose(a_m, a_u)
    _tree_allclose(s_m, s_u)


# ---------------------------------------------------------------------------
# LM backend: cached fed round vs recompute fed round
# ---------------------------------------------------------------------------


def test_lm_cached_fed_round_matches_recompute():
    model = build(LM_CFG)
    params = model.init(jax.random.PRNGKey(0))
    plan = freezing.make_stage_plan(LM_CFG, 1)
    frozen, active = freezing.init_stage_active(model, params, plan,
                                                jax.random.PRNGKey(1))
    num_pods, K = 2, 2
    b = make_lm_batch(LM_CFG, 2, 32)
    batch = {k: jnp.asarray(np.stack([np.stack([v] * K)] * num_pods))
             for k, v in b.items()}
    w = jnp.asarray([1.0, 3.0])

    rstep = freezing.make_fed_round_step(model, plan, sgd(0.05),
                                         num_pods=num_pods, local_steps=K,
                                         remat=False)
    ref_active, ref_m = jax.jit(rstep)(active, frozen, batch, w)

    # precompute prefix features for every (pod, step) minibatch
    pf = jax.jit(lambda f, a, bb: freezing.stage_prefix_features(
        model, f, a, bb, plan))
    h0 = []
    aux0 = []
    for p in range(num_pods):
        hs, auxs = [], []
        for k in range(K):
            hh, aa = pf(frozen, active, {kk: vv[p, k] for kk, vv in batch.items()})
            hs.append(hh)
            auxs.append(aa)
        h0.append(jnp.stack(hs))
        aux0.append(jnp.stack(auxs))
    cbatch = dict(batch)
    cbatch["h0"] = jnp.stack(h0)
    cbatch["aux0"] = jnp.stack(aux0)
    cstep = make_lm_cached_fed_round_step(model, plan, sgd(0.05),
                                          num_pods=num_pods, local_steps=K,
                                          remat=False, donate=False)
    got_active, got_m = cstep(active, cbatch, w)
    _tree_allclose(got_active, ref_active, rtol=2e-2, atol=2e-2)  # bf16
    np.testing.assert_allclose(float(got_m["loss"]), float(ref_m["loss"]),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# memory-model hook: the selector declines the cache on memory-poor clients
# ---------------------------------------------------------------------------


def test_memory_hook_cache_monotone():
    model = CNN(TINY)
    base = cnn_stage_memory_bytes(model, 1, 32, 16)
    with_cache = cnn_stage_memory_bytes(model, 1, 32, 16, cache_samples=500)
    assert with_cache > base
    from repro.core.memory_model import stage_memory_bytes
    lm_base = stage_memory_bytes(LM_CFG, 1, batch=2, seq=32)["total"]
    lm_cache = stage_memory_bytes(LM_CFG, 1, batch=2, seq=32,
                                  cache_tokens=10_000)
    assert lm_cache["total"] > lm_base
    assert lm_cache["feature_cache"] > 0


def test_server_declines_cache_on_memory_poor_clients():
    train, clients, model, params, state = _cnn_world()
    # one client barely fits the stage but NOT the cache
    model_req = cnn_stage_memory_bytes(model, 1, 32, 16)
    clients[0].memory_bytes = model_req + 1.0
    clients[1].memory_bytes = 64 * 2**30
    srv = SmartFreezeServer(model, clients, clients_per_round=4, batch_size=32)
    plan = srv._cache_plan(1)
    assert plan[clients[0].client_id] is np.False_ or not plan[clients[0].client_id]
    assert plan[clients[1].client_id]
    assert srv._cache_plan(0) == {}  # stage 0 has no frozen prefix
