"""Progressive-freezing invariants: split/merge roundtrip, frozen params
truly frozen, optimizer state covers only the active block, stage memory
shrinks, fed round reduces to the weighted average."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import freezing
from repro.data.synthetic import make_lm_batch
from repro.models.module import param_count, tree_paths
from repro.models.transformer import build
from repro.optim import adamw, sgd

CFG = configs.get("llama3-8b").reduced(num_layers=4, num_freeze_blocks=2)


def _setup(stage):
    model = build(CFG)
    params = model.init(jax.random.PRNGKey(0))
    plan = freezing.make_stage_plan(CFG, stage)
    frozen, active = freezing.init_stage_active(model, params, plan,
                                                jax.random.PRNGKey(1))
    return model, params, plan, frozen, active


def test_split_merge_roundtrip():
    model, params, plan, frozen, active = _setup(1)
    active.pop("op", None)
    merged = freezing.merge_stage_params(model, params, plan, active)
    for (p1, l1), (p2, l2) in zip(tree_paths(params), tree_paths(merged)):
        assert p1 == p2
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))


def test_frozen_params_unchanged_by_training():
    model, params, plan, frozen, active = _setup(1)
    opt = adamw(1e-2)
    step = jax.jit(freezing.make_train_step(model, plan, opt, remat=False))
    state = freezing.TrainState(active, frozen, opt.init(active), jnp.int32(0))
    batch = {k: jnp.asarray(v) for k, v in make_lm_batch(CFG, 2, 32).items()}
    for _ in range(3):
        state, _ = step(state, batch)
    # frozen tree is untouched by construction; active must have changed
    changed = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                      - b.astype(jnp.float32)).max()),
                           state.active["runs"], active["runs"])
    assert max(jax.tree.leaves(changed)) > 0


def test_optimizer_state_only_active_block():
    model, params, plan, frozen, active = _setup(1)
    opt = adamw(1e-2)
    ost = opt.init(active)
    n_active = param_count(active)
    n_total = param_count(params)
    n_m = param_count(ost["m"])
    assert n_m == n_active
    assert n_active < n_total  # the paper's M_optimizer saving


def test_stage0_trains_embed_stage1_not():
    p0 = freezing.make_stage_plan(CFG, 0)
    p1 = freezing.make_stage_plan(CFG, 1)
    assert p0.train_embed and not p1.train_embed
    assert p0.final is False and p1.final is True  # 2 blocks


def test_fed_round_equals_weighted_average_of_local():
    model, params, plan, frozen, active = _setup(0)
    num_pods, K = 2, 2
    rstep = jax.jit(freezing.make_fed_round_step(
        model, plan, sgd(0.05), num_pods=num_pods, local_steps=K, remat=False))
    b = make_lm_batch(CFG, 2, 32)
    batch = {k: jnp.broadcast_to(jnp.asarray(v), (num_pods, K) + v.shape)
             for k, v in b.items()}
    w = jnp.asarray([1.0, 3.0])
    new_active, _ = rstep(active, frozen, batch, w)
    # identical pods (same data, same init) -> average == each local result
    rstep1 = jax.jit(freezing.make_fed_round_step(
        model, plan, sgd(0.05), num_pods=1, local_steps=K, remat=False))
    batch1 = {k: v[:1] for k, v in batch.items()}
    solo, _ = rstep1(active, frozen, batch1, jnp.asarray([1.0]))
    for a, b_ in zip(jax.tree.leaves(new_active), jax.tree.leaves(solo)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32), rtol=2e-2,
                                   atol=2e-2)


def test_stage_memory_model_decreases():
    from repro.core.memory_model import full_model_memory_bytes, stage_memory_bytes

    cfg = configs.get("llama3-8b")
    full = full_model_memory_bytes(cfg, batch=8, seq=4096)["total"]
    for stage in range(cfg.num_freeze_blocks):
        st = stage_memory_bytes(cfg, stage, batch=8, seq=4096)["total"]
        assert st < full, (stage, st, full)
