"""Mixture-of-Experts FFN.

Baseline implementation is the GShard dual-einsum formulation with a capacity
factor, *chunked over the sequence* (lax.scan) so the dispatch/combine
one-hots stay O(chunk² · k · cf / S²) of the naive cost — with chunk=128 the
dispatch einsums are ~3% of expert FLOPs for deepseek-v2 and negligible for
grok-1 (napkin math in DESIGN.md §4).

Sharding (logical axes):
  "expert"    — expert dim. deepseek-v2 maps it to "model" (expert-parallel,
                160/16 = 10 experts/chip; GSPMD inserts the all-to-alls around
                the dispatch einsums). grok-1 leaves it unsharded and maps
                "moe_ff" to "model" (expert tensor-parallel, 32768/16 = 2048).
  "moe_ff"    — per-expert hidden dim.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import activation, dense, dense_init
from repro.models.module import PFac, Params

MOE_CHUNK = 128  # sequence chunk for dispatch (divides all assigned seq lens)


def moe_init(fac: PFac, cfg: ArchConfig) -> Params:
    """Axes convention: paths recorded relative to ``fac`` mirror the returned
    dict exactly (caller passes ``fac.sub(<key it stores this under>)``)."""
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p: Params = {
        "router": fac.param("router", (d, E), (None, "expert"), init="normal",
                            dtype=jnp.float32),
        "w_gate": fac.param("w_gate", (E, d, ff), ("expert", None, "moe_ff"), init="normal", fan_in=d),
        "w_up": fac.param("w_up", (E, d, ff), ("expert", None, "moe_ff"), init="normal", fan_in=d),
        "w_down": fac.param("w_down", (E, ff, d), ("expert", "moe_ff", None), init="normal", fan_in=ff),
    }
    if cfg.num_shared_experts > 0:
        sff = cfg.num_shared_experts * ff
        p["shared_gate"] = dense_init(fac, "shared_gate", d, sff, (None, "mlp"))
        p["shared_up"] = dense_init(fac, "shared_up", d, sff, (None, "mlp"))
        p["shared_down"] = dense_init(fac, "shared_down", sff, d, ("mlp", None))
    return p


def _capacity(chunk_tokens: int, cfg: ArchConfig) -> int:
    c = int(chunk_tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.num_experts)
    return max(c, 1)


def _dispatch_combine(x: jnp.ndarray, p: Params, cfg: ArchConfig
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """GShard top-k dispatch for one chunk. x: [B, Sc, d].

    Returns (dispatch [B,Sc,E,C] bf16 one-hot, combine [B,Sc,E,C], aux_loss).
    """
    B, Sc, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = _capacity(Sc, cfg)
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [B,Sc,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # [B,Sc,k]
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # [B,Sc,k,E]
    # position of each (token, choice) within its expert queue: cumulate over
    # the flattened (Sc*k) token-choice order (earlier tokens win capacity)
    flat = onehot.reshape(B, Sc * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # slots used before this choice
    pos = pos.reshape(B, Sc, k, E)
    within = (pos < C)
    keep = onehot * within.astype(jnp.float32)
    slot = jax.nn.one_hot(jnp.sum(pos * onehot, axis=-1).astype(jnp.int32), C,
                          dtype=jnp.float32)  # [B,Sc,k,C]
    # dispatch[b,s,e,c] = 1 if choice routed to expert e slot c
    disp = jnp.einsum("bske,bskc->bsec", keep, slot)
    comb = jnp.einsum("bske,bskc,bsk->bsec", keep, slot, gate_vals)
    # expert-level load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
    ce = jnp.mean(onehot.sum(2), axis=(0, 1))  # fraction routed per expert
    aux = jnp.sum(me * ce) * (E / k)
    return disp.astype(x.dtype), comb.astype(x.dtype), aux


def _expert_ffn(p: Params, xin: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """xin: [B, E, C, d] -> [B, E, C, d]; batched over experts."""
    act = activation(cfg.mlp_activation)
    g = jnp.einsum("becd,edf->becf", xin, p["w_gate"].astype(xin.dtype))
    u = jnp.einsum("becd,edf->becf", xin, p["w_up"].astype(xin.dtype))
    h = act(g) * u
    return jnp.einsum("becf,efd->becd", h, p["w_down"].astype(xin.dtype))


def moe_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN. x: [B, S, d] -> ([B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    chunk = min(MOE_CHUNK, S)
    assert S % chunk == 0, f"seq {S} not divisible by moe chunk {chunk}"
    n = S // chunk

    def body(carry, xc):  # xc: [B, chunk, d]
        disp, comb, aux = _dispatch_combine(xc, p, cfg)
        xin = jnp.einsum("bsec,bsd->becd", disp, xc)
        out = _expert_ffn(p, xin, cfg)
        yc = jnp.einsum("becd,bsec->bsd", out, comb)
        return carry + aux, yc

    xs = x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)  # [n, B, chunk, d]
    aux_total, ys = jax.lax.scan(body, jnp.float32(0.0), xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, d)

    if cfg.num_shared_experts > 0:
        act = activation(cfg.mlp_activation)
        shared = dense(p["shared_down"],
                       act(dense(p["shared_gate"], x)) * dense(p["shared_up"], x))
        y = y + shared
    return y, aux_total / n


def moe_decode(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Decode-path MoE for a single token per sequence. x: [B, 1, d].

    Reuses the capacity-based GShard dispatch with the *batch* as the token
    group (one token/seq): expert compute stays proportional to B·k slots.
    Capacity factor is doubled at decode to make token drops negligible.
    """
    import dataclasses

    B, _, d = x.shape
    dcfg = dataclasses.replace(cfg, capacity_factor=cfg.capacity_factor * 2)
    xt = x.reshape(1, B, d)  # [1, B(tokens), d]
    disp, comb, _ = _dispatch_combine(xt, p, dcfg)
    xin = jnp.einsum("bsec,bsd->becd", disp, xt)
    out = _expert_ffn(p, xin, cfg)
    y = jnp.einsum("becd,bsec->bsd", out, comb).reshape(B, 1, d)
    if cfg.num_shared_experts > 0:
        act = activation(cfg.mlp_activation)
        shared = dense(p["shared_down"],
                       act(dense(p["shared_gate"], x)) * dense(p["shared_up"], x))
        y = y + shared
    return y
