"""Minimal functional module system.

Models are pure functions over parameter pytrees (nested dicts of jnp arrays).
``PFac`` is the single source of truth for parameter creation: it initializes
the array AND records the parameter's *logical sharding axes* (a tuple of
logical axis names, one per array dim, or None). ``dist.sharding`` later maps
logical axes -> mesh ``PartitionSpec``s.

Abstract (no-allocation) parameter trees come for free via
``jax.eval_shape(model.init, rng)`` — the dry-run uses that to build
ShapeDtypeStructs for a 236B model without touching memory.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
Axes = Tuple[Optional[str], ...]


class PFac:
    """Parameter factory with rng-folding scopes and logical-axis recording."""

    def __init__(self, rng, dtype=jnp.float32, *, axes_store: Optional[dict] = None,
                 path: Tuple[str, ...] = ()):
        self.rng = rng
        self.dtype = dtype
        self.axes_store = axes_store if axes_store is not None else {}
        self.path = path

    def sub(self, name: str) -> "PFac":
        rng = jax.random.fold_in(self.rng, _stable_hash(name))
        return PFac(rng, self.dtype, axes_store=self.axes_store,
                    path=self.path + (name,))

    def param(self, name: str, shape: Tuple[int, ...], axes: Axes, *,
              init: str = "normal", scale: float = 1.0, fan_in: Optional[int] = None,
              dtype=None) -> jnp.ndarray:
        assert len(axes) == len(shape), f"{self.path + (name,)}: axes {axes} vs shape {shape}"
        self.axes_store[self.path + (name,)] = axes
        dtype = dtype or self.dtype
        rng = jax.random.fold_in(self.rng, _stable_hash(name))
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            fi = fan_in if fan_in is not None else (shape[0] if len(shape) > 1 else shape[-1])
            std = scale / math.sqrt(max(fi, 1))
            return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)
        if init == "uniform":
            fi = fan_in if fan_in is not None else (shape[0] if len(shape) > 1 else shape[-1])
            lim = scale * math.sqrt(3.0 / max(fi, 1))
            return jax.random.uniform(rng, shape, jnp.float32, -lim, lim).astype(dtype)
        if init == "embed":
            return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)
        raise ValueError(f"unknown init {init}")


def _stable_hash(s: str) -> int:
    h = 2166136261
    for c in s.encode():
        h = ((h ^ c) * 16777619) & 0x7FFFFFFF
    return h


# ---------------------------------------------------------------------------
# Axis-tree utilities
# ---------------------------------------------------------------------------


def axes_to_tree(axes_store: dict) -> dict:
    """Nested dict mirroring the param tree, leaves = logical-axes tuples."""
    root: dict = {}
    for path, axes in axes_store.items():
        node = root
        for key in path[:-1]:
            node = node.setdefault(key, {})
        node[path[-1]] = axes
    return root


def prepend_axis(axes_tree, axis_name: Optional[str]):
    """Prepend a leading logical axis (e.g. 'layers') to every leaf."""
    return jax.tree.map(
        lambda a: (axis_name,) + tuple(a),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def init_stack(fac: PFac, n: int, layer_init: Callable[[PFac], Params],
               stack_axis_name: Optional[str] = "layers") -> Params:
    """Initialize ``n`` stacked copies of a layer (for lax.scan-over-layers).

    The per-layer init runs under vmap so arrays get a leading [n] dim; the
    recorded logical axes get ``stack_axis_name`` prepended.
    """
    inner_store: dict = {}

    def one(rng):
        f = PFac(rng, fac.dtype, axes_store=inner_store, path=())
        return layer_init(f)

    rngs = jax.random.split(fac.rng, n)
    params = jax.vmap(one)(rngs)
    for path, axes in inner_store.items():
        fac.axes_store[fac.path + path] = (stack_axis_name,) + tuple(axes)
    return params


def slice_stack(stacked: Params, lo: int, hi: int) -> Params:
    """Static slice [lo:hi) of every leaf's leading (layer) dim."""
    return jax.tree.map(lambda x: x[lo:hi], stacked)


def tree_paths(tree) -> list:
    """Flat list of (path_tuple, leaf)."""
    out = []
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        path = tuple(
            k.key if isinstance(k, jax.tree_util.DictKey) else str(k) for k in kp
        )
        out.append((path, leaf))
    return out


def param_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def param_count(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
