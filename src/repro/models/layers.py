"""Core layer primitives: dense, norms, RoPE, activations, conv (for CNNs)."""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# §Perf opt: bf16 matmul accumulation. jnp's default f32 accumulation makes
# every TP row-parallel psum carry f32 activations (2x bytes); bf16 psum
# halves the collective+memory terms at a small accuracy cost (weights stay
# bf16 either way; the loss/norm math stays f32).
_BF16_DOTS = os.environ.get("REPRO_BF16_DOTS", "0") == "1"

from repro.models.module import PFac, Params

# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


def dense_init(fac: PFac, name: str, d_in: int, d_out: int, axes, *,
               bias: bool = False, scale: float = 1.0) -> Params:
    sub = fac.sub(name)
    p = {"w": sub.param("w", (d_in, d_out), axes, init="normal", scale=scale)}
    if bias:
        p["b"] = sub.param("b", (d_out,), (axes[-1],), init="zeros")
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    w = p["w"].astype(x.dtype)
    if _BF16_DOTS and x.dtype == jnp.bfloat16:
        y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.bfloat16)
    else:
        y = x @ w
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms (computed in fp32, cast back)
# ---------------------------------------------------------------------------


def rmsnorm_init(fac: PFac, name: str, d: int) -> Params:
    return {"scale": fac.sub(name).param("scale", (d,), (None,), init="ones")}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(fac: PFac, name: str, d: int) -> Params:
    sub = fac.sub(name)
    return {"scale": sub.param("scale", (d,), (None,), init="ones"),
            "bias": sub.param("bias", (d,), (None,), init="zeros")}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def norm_init(fac: PFac, name: str, d: int, kind: str) -> Params:
    return layernorm_init(fac, name, d) if kind == "layernorm" else rmsnorm_init(fac, name, d)


def norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-5) -> jnp.ndarray:
    return layernorm(p, x, eps) if kind == "layernorm" else rmsnorm(p, x, eps)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape [head_dim // 2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Conv (CNN repro models + SSM causal conv1d)
# ---------------------------------------------------------------------------


def conv2d_init(fac: PFac, name: str, c_in: int, c_out: int, k: int, *,
                bias: bool = True) -> Params:
    sub = fac.sub(name)
    p = {"w": sub.param("w", (k, k, c_in, c_out), (None, None, None, "mlp"),
                        init="normal", fan_in=k * k * c_in, scale=1.414)}
    if bias:
        p["b"] = sub.param("b", (c_out,), ("mlp",), init="zeros")
    return p


def conv2d(p: Params, x: jnp.ndarray, stride: int = 1, padding: str = "SAME") -> jnp.ndarray:
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def causal_conv1d_init(fac: PFac, name: str, channels: int, k: int) -> Params:
    sub = fac.sub(name)
    return {"w": sub.param("w", (k, channels), (None, "mlp"), init="normal", fan_in=k),
            "b": sub.param("b", (channels,), ("mlp",), init="zeros")}


def causal_conv1d(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time. x: [batch, seq, channels]."""
    k = p["w"].shape[0]
    w = p["w"].astype(x.dtype)  # [k, C]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # depthwise: sum over taps of shifted inputs (k is tiny, unrolled)
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + pad[:, i:i + x.shape[1], :] * w[i]
    return y + p["b"].astype(x.dtype)


def causal_conv1d_step(p: Params, x_t: jnp.ndarray, conv_state: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step. x_t: [batch, C]; conv_state: [batch, k-1, C]."""
    w = p["w"].astype(x_t.dtype)
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [b, k, C]
    y = jnp.einsum("bkc,kc->bc", window, w) + p["b"].astype(x_t.dtype)
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# BatchNorm (CNN repro; running stats carried in a separate state tree)
# ---------------------------------------------------------------------------


def batchnorm_init(fac: PFac, name: str, c: int) -> Tuple[Params, Params]:
    sub = fac.sub(name)
    params = {"scale": sub.param("scale", (c,), (None,), init="ones"),
              "bias": sub.param("bias", (c,), (None,), init="zeros")}
    state = {"mean": jnp.zeros((c,), jnp.float32),
             "var": jnp.ones((c,), jnp.float32)}
    return params, state


def batchnorm(p: Params, s: Params, x: jnp.ndarray, *, train: bool,
              momentum: float = 0.9, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(xf, axis=axes)
        var = jnp.var(xf, axis=axes)
        new_s = {"mean": momentum * s["mean"] + (1 - momentum) * mean,
                 "var": momentum * s["var"] + (1 - momentum) * var}
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype), new_s
