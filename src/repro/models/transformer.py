"""Generic LM assembly: embed → segments of scanned homogeneous layers → head.

Layer kinds: attn_mlp (dense), attn_moe, mamba2, mlstm, slstm, shared_attn
(zamba2's weight-tied attention blocks — 2 alternating sets).

The class exposes the decomposed interface SmartFreeze's progressive trainer
needs: ``embed`` / ``run_layers(lo, hi)`` / ``head``, where run_layers slices
stacked scan parameters at arbitrary (static) layer boundaries so a freeze
block never has to align with a segment.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import activation, dense, dense_init, norm, norm_init
from repro.models.module import (PFac, Params, axes_to_tree, init_stack,
                                 slice_stack)


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------


def mlp_init(fac: PFac, cfg: ArchConfig, d_ff: int) -> Params:
    d = cfg.d_model
    return {"gate": dense_init(fac, "gate", d, d_ff, (None, "mlp")),
            "up": dense_init(fac, "up", d, d_ff, (None, "mlp")),
            "down": dense_init(fac, "down", d_ff, d, ("mlp", None))}


def mlp_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    act = activation(cfg.mlp_activation)
    return dense(p["down"], act(dense(p["gate"], x)) * dense(p["up"], x))


def layer_init(fac: PFac, cfg: ArchConfig, kind: str) -> Params:
    p: Params = {}
    if kind in ("attn_mlp", "attn_moe", "shared_attn"):
        p["ln1"] = norm_init(fac, "ln1", cfg.d_model, cfg.norm)
        p["attn"] = attn.attn_init(fac.sub("attn"), cfg)
        p["ln2"] = norm_init(fac, "ln2", cfg.d_model, cfg.norm)
        if kind == "attn_moe":
            p["moe"] = moe_mod.moe_init(fac.sub("moe"), cfg)
        else:
            p["mlp"] = mlp_init(fac.sub("mlp"), cfg, cfg.d_ff)
    elif kind == "mamba2":
        p["ln"] = norm_init(fac, "ln", cfg.d_model, cfg.norm)
        p["mix"] = ssm_mod.mamba2_init(fac.sub("mix"), cfg)
    elif kind == "mlstm":
        p["ln"] = norm_init(fac, "ln", cfg.d_model, cfg.norm)
        p["mix"] = ssm_mod.mlstm_init(fac.sub("mix"), cfg)
    elif kind == "slstm":
        p["ln"] = norm_init(fac, "ln", cfg.d_model, cfg.norm)
        p["mix"] = ssm_mod.slstm_init(fac.sub("mix"), cfg)
    else:
        raise ValueError(kind)
    return p


def layer_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, kind: str, *,
                causal: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-seq layer. Returns (y, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind in ("attn_mlp", "attn_moe", "shared_attn"):
        h = x + attn.attn_forward(p["attn"], norm(p["ln1"], x, cfg.norm, cfg.norm_eps),
                                  cfg, causal=causal)
        hn = norm(p["ln2"], h, cfg.norm, cfg.norm_eps)
        if kind == "attn_moe":
            y, aux = moe_mod.moe_forward(p["moe"], hn, cfg)
        else:
            y = mlp_apply(p["mlp"], hn, cfg)
        return h + y, aux
    # ssm/recurrent kinds
    fn = {"mamba2": ssm_mod.mamba2_forward, "mlstm": ssm_mod.mlstm_forward,
          "slstm": ssm_mod.slstm_forward}[kind]
    return x + fn(p["mix"], norm(p["ln"], x, cfg.norm, cfg.norm_eps), cfg), aux


def layer_init_cache(cfg: ArchConfig, kind: str, batch: int, max_seq: int, dtype):
    if kind in ("attn_mlp", "attn_moe", "shared_attn"):
        return attn.attn_init_cache(cfg, batch, max_seq, dtype)
    fn = {"mamba2": ssm_mod.mamba2_init_state, "mlstm": ssm_mod.mlstm_init_state,
          "slstm": ssm_mod.slstm_init_state}[kind]
    return fn(cfg, batch, dtype)


def layer_decode(p: Params, x: jnp.ndarray, cache, pos, cfg: ArchConfig, kind: str):
    if kind in ("attn_mlp", "attn_moe", "shared_attn"):
        a, cache = attn.attn_decode(p["attn"], norm(p["ln1"], x, cfg.norm, cfg.norm_eps),
                                    cache, pos, cfg)
        h = x + a
        hn = norm(p["ln2"], h, cfg.norm, cfg.norm_eps)
        if kind == "attn_moe":
            y = moe_mod.moe_decode(p["moe"], hn, cfg)
        else:
            y = mlp_apply(p["mlp"], hn, cfg)
        return h + y, cache
    fn = {"mamba2": ssm_mod.mamba2_step, "mlstm": ssm_mod.mlstm_step,
          "slstm": ssm_mod.slstm_step}[kind]
    y, cache = fn(p["mix"], norm(p["ln"], x, cfg.norm, cfg.norm_eps), cache, cfg)
    return x + y, cache


# ---------------------------------------------------------------------------
# The LM
# ---------------------------------------------------------------------------


@dataclass
class LM:
    cfg: ArchConfig

    # ----- construction -----

    def _build(self, fac: PFac) -> Params:
        cfg = self.cfg
        p: Params = {}
        p["embed"] = fac.param("embed", (cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), init="embed", scale=0.02)
        if cfg.modality == "vision_stub":
            ff = fac.sub("frontend")
            p["frontend"] = {
                "proj1": dense_init(ff, "proj1", cfg.frontend_dim, cfg.d_model,
                                    (None, "embed")),
                "proj2": dense_init(ff, "proj2", cfg.d_model, cfg.d_model,
                                    ("embed", None)),
            }
        elif cfg.modality == "audio_stub":
            ff = fac.sub("frontend")
            p["frontend"] = {
                "proj": dense_init(ff, "proj", cfg.frontend_dim, cfg.d_model,
                                   (None, "embed")),
            }
        segf = fac.sub("segments")
        segs: Params = {}
        for i, (kind, n) in enumerate(cfg.segments()):
            if kind == "shared_attn":
                segs[str(i)] = {}  # weights live in p["shared_attn"]
            else:
                segs[str(i)] = init_stack(segf.sub(str(i)), n,
                                          lambda f, k=kind: layer_init(f, cfg, k))
        p["segments"] = segs
        if any(k == "shared_attn" for k, _ in cfg.segments()):
            nsets = max(cfg.num_shared_attn_sets, 1)
            saf = fac.sub("shared_attn")
            p["shared_attn"] = {str(j): layer_init(saf.sub(str(j)), cfg, "shared_attn")
                                for j in range(nsets)}
        p["final_norm"] = norm_init(fac, "final_norm", cfg.d_model, cfg.norm)
        if not cfg.tie_embeddings:
            p["head"] = dense_init(fac, "head", cfg.d_model, cfg.vocab_size,
                                   ("embed", "vocab"))
        return p

    def init(self, rng) -> Params:
        fac = PFac(rng, dtype=_dt(self.cfg.param_dtype))
        params = self._build(fac)
        self._axes_store = dict(fac.axes_store)
        return params

    def abstract_params(self) -> Params:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def axes_tree(self) -> Dict:
        if not hasattr(self, "_axes_store"):
            self.abstract_params()  # traces init, records axes
        tree = axes_to_tree(self._axes_store)
        # shared_attn segments own no params: mirror their empty dicts so the
        # axes tree has the SAME pytree structure as the param tree
        segs = tree.setdefault("segments", {})
        for i, (kind, n) in enumerate(self.cfg.segments()):
            if kind == "shared_attn":
                segs.setdefault(str(i), {})
        return tree

    # ----- segment bookkeeping -----

    def _seg_table(self) -> List[Tuple[str, int, int, int]]:
        """List of (kind, seg_index, layer_lo, layer_hi)."""
        out, lo = [], 0
        for i, (kind, n) in enumerate(self.cfg.segments()):
            out.append((kind, i, lo, lo + n))
            lo += n
        return out

    def _shared_attn_index(self, layer_idx: int) -> int:
        """Which tied weight set the shared-attn occurrence at layer_idx uses."""
        occ = 0
        for j, k in enumerate(self.cfg.layer_kinds()):
            if j == layer_idx:
                break
            if k == "shared_attn":
                occ += 1
        nsets = max(self.cfg.num_shared_attn_sets, 1)
        return occ % nsets

    # ----- forward pieces -----

    def embed(self, params: Params, batch: Dict) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.modality == "audio_stub":
            return dense(params["frontend"]["proj"],
                         batch["frames"].astype(_dt(cfg.compute_dtype)))
        tok = params["embed"]
        h = tok[batch["tokens"]].astype(_dt(cfg.compute_dtype))
        if cfg.modality == "vision_stub" and "patches" in batch:
            fp = params["frontend"]
            pe = dense(fp["proj2"], jax.nn.gelu(
                dense(fp["proj1"], batch["patches"].astype(h.dtype))))
            h = jnp.concatenate([pe, h], axis=1)
        return h

    def run_layers(self, params: Params, h: jnp.ndarray, lo: int, hi: int,
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Run layers [lo, hi) full-sequence. Returns (h, aux_loss)."""
        cfg = self.cfg
        causal = not cfg.is_encoder_only
        aux = jnp.float32(0.0)
        for kind, si, s_lo, s_hi in self._seg_table():
            a, b = max(lo, s_lo), min(hi, s_hi)
            if a >= b:
                continue
            if kind == "shared_attn":
                sp = params["shared_attn"][str(self._shared_attn_index(s_lo))]
                h, al = layer_apply(sp, h, cfg, kind, causal=causal)
                aux = aux + al
            else:
                sliced = slice_stack(params["segments"][str(si)], a - s_lo, b - s_lo)

                def body(carry, lp, k=kind):
                    hh, ax = carry
                    hh, al = layer_apply(lp, hh, cfg, k, causal=causal)
                    return (hh, ax + al), None

                (h, aux), _ = jax.lax.scan(body, (h, aux), sliced)
        return h, aux

    def head(self, params: Params, h: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        h = norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        if cfg.tie_embeddings:
            return h @ params["embed"].T.astype(h.dtype)
        return dense(params["head"], h)

    def forward(self, params: Params, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full forward. Returns (logits, aux_loss)."""
        from repro.dist.sharding import shard_batch

        h = shard_batch(self.embed(params, batch), batch_axes=self.cfg.batch_axes)
        h, aux = self.run_layers(params, h, 0, self.cfg.num_layers)
        return self.head(params, h), aux

    def loss(self, params: Params, batch: Dict) -> jnp.ndarray:
        """Chunked-CE loss: never materializes [B, S, V] logits."""
        from repro.dist.sharding import shard_batch

        cfg = self.cfg
        h = shard_batch(self.embed(params, batch), batch_axes=self.cfg.batch_axes)
        h, aux = self.run_layers(params, h, 0, cfg.num_layers)
        h = norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
        head_w = params["embed"].T if cfg.tie_embeddings else params["head"]["w"]
        return chunked_ce_loss(h, head_w, batch, cfg) + 0.01 * aux

    # ----- decode -----

    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        dtype = _dt(cfg.compute_dtype)
        caches = {}
        for kind, si, s_lo, s_hi in self._seg_table():
            if kind == "shared_attn":
                caches[str(si)] = layer_init_cache(cfg, kind, batch, max_seq, dtype)
            else:
                one = jax.eval_shape(
                    lambda k=kind: layer_init_cache(cfg, k, batch, max_seq, dtype))
                n = s_hi - s_lo
                caches[str(si)] = jax.tree.map(
                    lambda sd: jnp.zeros((n,) + sd.shape, sd.dtype), one)
        return caches

    def decode_step(self, params: Params, batch: Dict, cache: Dict, pos
                    ) -> Tuple[jnp.ndarray, Dict]:
        """One-token decode. batch['tokens']: [B, 1]. Returns (logits, cache)."""
        from repro.dist.sharding import shard_batch

        cfg = self.cfg
        h = shard_batch(params["embed"][batch["tokens"]].astype(_dt(cfg.compute_dtype)),
                        batch_axes=cfg.batch_axes)
        new_caches = {}
        for kind, si, s_lo, s_hi in self._seg_table():
            if kind == "shared_attn":
                sp = params["shared_attn"][str(self._shared_attn_index(s_lo))]
                h, c = layer_decode(sp, h, cache[str(si)], pos, cfg, kind)
                new_caches[str(si)] = c
            else:
                def body(hh, xs, k=kind):
                    lp, lc = xs
                    hh, c = layer_decode(lp, hh, lc, pos, cfg, k)
                    return hh, c

                h, c = jax.lax.scan(body, h, (params["segments"][str(si)], cache[str(si)]))
                new_caches[str(si)] = c
        return self.head(params, h), new_caches


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def token_loss(logits: jnp.ndarray, batch: Dict, cfg: ArchConfig) -> jnp.ndarray:
    """Mean cross-entropy against batch['labels'] (mask label < 0)."""
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    if cfg.modality == "vision_stub" and lf.shape[1] != labels.shape[1]:
        lf = lf[:, lf.shape[1] - labels.shape[1]:, :]  # text positions only
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _vocab_logits_spec(vocab_size: int, batch: int, batch_axes):
    """P(batch_axes, None, "model") under the ambient mesh — chunk logits are
    sharded on batch (data axes) AND vocab (model axis)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = list(mesh.axis_names)
        shape = dict(mesh.shape)
    except Exception:  # noqa: BLE001
        return None
    if not names:
        return None
    from jax.sharding import PartitionSpec as P

    v = "model" if "model" in names and vocab_size % shape["model"] == 0 else None
    baxes = tuple(a for a in batch_axes if a in names)
    if baxes and batch % int(np.prod([shape[a] for a in baxes])) == 0:
        b = baxes if len(baxes) > 1 else baxes[0]
    else:
        b = None
    if b is None and v is None:
        return None
    return P(b, None, v)


def chunked_ce_loss(h: jnp.ndarray, head_w: jnp.ndarray, batch: Dict,
                    cfg: ArchConfig, *, chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy WITHOUT materializing [B, S, V] logits.

    Scans over sequence chunks; each chunk's logits are sharded
    (batch, -, vocab->model) and rematerialized in the backward pass
    (jax.checkpoint), so peak memory is [B, chunk, V/model_shards] instead of
    the full fp32 logits tensor. head_w: [d, V].
    """
    labels = batch["labels"]
    if h.shape[1] != labels.shape[1]:  # vlm: loss over text positions only
        h = h[:, h.shape[1] - labels.shape[1]:, :]
    B, S, d = h.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c
    hs = h.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    ys = labels.reshape(B, n, c).transpose(1, 0, 2)
    spec = _vocab_logits_spec(head_w.shape[-1], B, cfg.batch_axes)

    @jax.checkpoint
    def chunk_loss(h_c, y_c):
        logits = (h_c @ head_w.astype(h_c.dtype)).astype(jnp.float32)
        if spec is not None:
            logits = jax.lax.with_sharding_constraint(logits, spec)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        m = (y_c >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * m), jnp.sum(m)

    def body(carry, xs):
        tot, cnt = carry
        l, m = chunk_loss(*xs)
        return (tot + l, cnt + m), None

    (total, count), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                     (hs, ys))
    return total / jnp.maximum(count, 1.0)


def build(cfg: ArchConfig) -> LM:
    return LM(cfg)
