"""State-space / recurrent layers: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Training/prefill uses *chunked* linear-attention forms (quadratic only within
a chunk, recurrent across chunks) so prefill_32k / train_4k never materialize
S×S score matrices. Decode uses O(1)-state single-step recurrences — this is
what makes long_500k runnable for the ssm/hybrid archs.

All recurrence math runs in fp32 with log-space decay (segsum) stabilizers.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (activation, causal_conv1d, causal_conv1d_init,
                                 causal_conv1d_step, dense, dense_init, rmsnorm,
                                 rmsnorm_init)
from repro.models.module import PFac, Params

SSM_CHUNK = 256


def _segsum(log_a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} log_a[..., k] for
    i >= j, -inf otherwise. log_a: [..., L] -> [..., L, L]."""
    L = log_a.shape[-1]
    cum = jnp.cumsum(log_a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # i, j -> sum_{j+1..i}
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def mamba2_init(fac: PFac, cfg: ArchConfig) -> Params:
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    nheads = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_ch = d_inner + 2 * N  # x, B, C all convolved
    return {
        "in_proj": dense_init(fac, "in_proj", D, 2 * d_inner + 2 * N + nheads,
                              ("qkv_in", "mlp")),
        "conv": causal_conv1d_init(fac, "conv", conv_ch, cfg.conv_kernel),
        "A_log": fac.param("A_log", (nheads,), (None,), init="zeros", dtype=jnp.float32),
        "D": fac.param("D", (nheads,), (None,), init="ones", dtype=jnp.float32),
        "dt_bias": fac.param("dt_bias", (nheads,), (None,), init="zeros", dtype=jnp.float32),
        "norm": rmsnorm_init(fac, "norm", d_inner),
        "out_proj": dense_init(fac, "out_proj", d_inner, D, ("mlp", "attn_out")),
    }


def _mamba2_split(p: Params, u: jnp.ndarray, cfg: ArchConfig):
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    nheads = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    zxbcdt = dense(p["in_proj"], u)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xbc, dt, d_inner, nheads, N


def mamba2_forward(p: Params, u: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """u: [B, S, D] -> [B, S, D] via chunked SSD."""
    Bsz, S, D = u.shape
    z, xbc, dt, d_inner, nheads, N = _mamba2_split(p, u, cfg)
    xbc = jax.nn.silu(causal_conv1d(p["conv"], xbc))
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    hd = cfg.ssm_head_dim
    x = x.reshape(Bsz, S, nheads, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])  # [H], negative
    log_a = (dt * A).astype(jnp.float32)  # [B,S,H] log decay per step

    y = _ssd_chunked(x, Bm, Cm, dt, log_a, chunk=min(SSM_CHUNK, S))
    y = y + (p["D"][:, None] * x.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(Bsz, S, d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out_proj"], y)


def _ssd_chunked(x, Bm, Cm, dt, log_a, *, chunk: int):
    """SSD scan. x: [B,S,H,hd]; Bm/Cm: [B,S,N]; dt/log_a: [B,S,H].

    Returns y: [B,S,H,hd]. State h: [B,H,hd,N].
    """
    Bsz, S, H, hd = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    xc = x.reshape(Bsz, n, chunk, H, hd).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, n, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, n, chunk, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, n, chunk, H)
    lac = log_a.reshape(Bsz, n, chunk, H)

    # intra-chunk (quadratic within chunk): y_intra[i] = sum_{j<=i} C_i.B_j L_ij dt_j x_j
    Lseg = _segsum(lac.transpose(0, 1, 3, 2))  # [B,n,H,c,c]
    att = jnp.einsum("bncN,bnmN->bncm", Cc, Bc)[:, :, None] * jnp.exp(Lseg)
    y_intra = jnp.einsum("bnhcm,bnmh,bnmhd->bnchd", att, dtc, xc)

    # chunk-final states: S_k = sum_j prod_{l>j} a_l dt_j x_j B_j^T
    tail = jnp.cumsum(lac, axis=2)
    tail = tail[:, :, -1:, :] - tail  # sum of log_a after position j
    w = jnp.exp(tail) * dtc  # [B,n,c,H]
    chunk_state = jnp.einsum("bnch,bnchd,bncN->bnhdN", w, xc, Bc)
    chunk_decay = jnp.exp(jnp.sum(lac, axis=2))  # [B,n,H]

    # inter-chunk recurrence over n chunks
    def body(h, inputs):
        st, dec = inputs
        h_new = dec[..., None, None] * h + st
        return h_new, h  # emit state *entering* the chunk

    h0 = jnp.zeros((Bsz, H, hd, N), jnp.float32)
    _, h_enter = jax.lax.scan(body, h0,
                              (chunk_state.transpose(1, 0, 2, 3, 4),
                               chunk_decay.transpose(1, 0, 2)))
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)  # [B,n,H,hd,N]

    # inter-chunk contribution: y_inter[i] = C_i . (prod_{l<=i} a_l) h_enter
    head = jnp.cumsum(lac, axis=2)  # sum log_a up to and incl. i
    y_inter = jnp.einsum("bncN,bnch,bnhdN->bnchd", Cc, jnp.exp(head), h_enter)
    y = (y_intra + y_inter).reshape(Bsz, S, H, hd)
    return y.astype(x.dtype)


def mamba2_init_state(cfg: ArchConfig, batch: int, dtype) -> Dict:
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_ch = d_inner + 2 * N
    return {"h": jnp.zeros((batch, nheads, cfg.ssm_head_dim, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_ch), dtype)}


def mamba2_step(p: Params, u: jnp.ndarray, state: Dict, cfg: ArchConfig
                ) -> Tuple[jnp.ndarray, Dict]:
    """Single decode step. u: [B, 1, D]."""
    Bsz = u.shape[0]
    z, xbc, dt, d_inner, nheads, N = _mamba2_split(p, u[:, 0, :], cfg)
    xbc, conv_state = causal_conv1d_step(p["conv"], xbc, state["conv"])
    xbc = jax.nn.silu(xbc)
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    hd = cfg.ssm_head_dim
    x = x.reshape(Bsz, nheads, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))  # [B,H]
    h = a[..., None, None] * state["h"] + jnp.einsum(
        "bh,bhd,bN->bhdN", dt, x, Bm.astype(jnp.float32))
    y = jnp.einsum("bN,bhdN->bhd", Cm.astype(jnp.float32), h)
    y = y + p["D"][:, None] * x
    y = y.reshape(Bsz, 1, d_inner).astype(u.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z[:, None, :]), cfg.norm_eps)
    return dense(p["out_proj"], y), {"h": h, "conv": conv_state}


# ===========================================================================
# mLSTM (xLSTM matrix-memory block)
# ===========================================================================


def mlstm_init(fac: PFac, cfg: ArchConfig) -> Params:
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    nheads = max(cfg.num_heads, 1)
    return {
        "up_proj": dense_init(fac, "up_proj", D, 2 * d_inner, ("qkv_in", "mlp")),
        "conv": causal_conv1d_init(fac, "conv", d_inner, cfg.conv_kernel),
        "wq": dense_init(fac, "wq", d_inner, d_inner, (None, "heads")),
        "wk": dense_init(fac, "wk", d_inner, d_inner, (None, "heads")),
        "wv": dense_init(fac, "wv", d_inner, d_inner, (None, "heads")),
        "w_if": fac.param("w_if", (d_inner, 2 * nheads), (None, None), init="normal"),
        "b_if": fac.param("b_if", (2 * nheads,), (None,), init="zeros"),
        "norm": rmsnorm_init(fac, "norm", d_inner),
        "down_proj": dense_init(fac, "down_proj", d_inner, D, ("mlp", "attn_out")),
    }


def mlstm_forward(p: Params, u: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """u: [B, S, D] -> [B, S, D] via chunked stabilized mLSTM."""
    Bsz, S, D = u.shape
    d_inner = cfg.ssm_expand * D
    H = max(cfg.num_heads, 1)
    hd = d_inner // H
    xz = dense(p["up_proj"], u)
    x, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(causal_conv1d(p["conv"], x))
    q = dense(p["wq"], xc).reshape(Bsz, S, H, hd)
    k = dense(p["wk"], xc).reshape(Bsz, S, H, hd) / jnp.sqrt(hd).astype(u.dtype)
    v = dense(p["wv"], x).reshape(Bsz, S, H, hd)
    gates = (xc @ p["w_if"].astype(xc.dtype) + p["b_if"].astype(xc.dtype)).astype(jnp.float32)
    log_i = gates[..., :H]  # pre-activation input gate (log-space)
    log_f = jax.nn.log_sigmoid(gates[..., H:])  # [B,S,H]

    y = _mlstm_chunked(q, k, v, log_i, log_f, chunk=min(SSM_CHUNK, S))
    y = y.reshape(Bsz, S, d_inner)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return dense(p["down_proj"], y)


def _mlstm_chunked(q, k, v, log_i, log_f, *, chunk: int):
    """Stabilized chunked mLSTM. q/k/v: [B,S,H,hd]; gates: [B,S,H].

    Within-chunk quadratic with decay matrix; cross-chunk recurrent matrix
    state C: [B,H,hd,hd], normalizer n: [B,H,hd]. Max-stabilizer folded into
    a per-position normalizer (denominator lower-bounded at exp(-m)·|qn|)."""
    Bsz, S, H, hd = q.shape
    n = S // chunk
    qc = q.reshape(Bsz, n, chunk, H, hd).astype(jnp.float32)
    kc = k.reshape(Bsz, n, chunk, H, hd).astype(jnp.float32)
    vc = v.reshape(Bsz, n, chunk, H, hd).astype(jnp.float32)
    lic = log_i.reshape(Bsz, n, chunk, H)
    lfc = log_f.reshape(Bsz, n, chunk, H)

    # decay matrix within chunk: D[i,j] = exp(sum_{l=j+1..i} log_f + log_i[j])
    Lseg = _segsum(lfc.transpose(0, 1, 3, 2))  # [B,n,H,c,c]
    logD = Lseg + lic.transpose(0, 1, 3, 2)[:, :, :, None, :]
    # stabilizer per query position
    m_intra = jnp.max(jnp.where(jnp.isfinite(logD), logD, -jnp.inf), axis=-1)
    head = jnp.cumsum(lfc, axis=2).transpose(0, 1, 3, 2)  # [B,n,H,c] decay to chunk start
    m = jnp.maximum(m_intra, head)  # conservative stabilizer, also covers inter term
    Dmat = jnp.exp(logD - m[..., None])
    scores = jnp.einsum("bnchd,bnmhd->bnhcm", qc, kc) * Dmat
    y_intra = jnp.einsum("bnhcm,bnmhd->bnchd", scores, vc)
    # intra normalizer: q_i . sum_j D_ij k_j == row-sum of the decayed scores
    n_intra = jnp.sum(scores, axis=-1)  # [B,n,H,c]

    # chunk-final state: C_k = sum_j exp(sum_{l>j} log_f + log_i[j]) k_j v_j^T
    tail = jnp.cumsum(lfc, axis=2)
    tail_total = tail[:, :, -1:, :]
    w = jnp.exp(tail_total - tail + lic)  # [B,n,c,H]
    chunk_C = jnp.einsum("bnch,bnchd,bnche->bnhde", w, kc, vc)
    chunk_N = jnp.einsum("bnch,bnchd->bnhd", w, kc)
    chunk_decay = jnp.exp(jnp.sum(lfc, axis=2))  # [B,n,H]

    def body(carry, inputs):
        C, Nrm = carry
        Ck, Nk, dec = inputs
        C_new = dec[..., None, None] * C + Ck
        N_new = dec[..., None] * Nrm + Nk
        return (C_new, N_new), (C, Nrm)

    C0 = jnp.zeros((Bsz, H, hd, hd), jnp.float32)
    N0 = jnp.zeros((Bsz, H, hd), jnp.float32)
    _, (C_enter, N_enter) = jax.lax.scan(
        body, (C0, N0),
        (chunk_C.transpose(1, 0, 2, 3, 4), chunk_N.transpose(1, 0, 2, 3),
         chunk_decay.transpose(1, 0, 2)))
    C_enter = C_enter.transpose(1, 0, 2, 3, 4)
    N_enter = N_enter.transpose(1, 0, 2, 3)

    inter_w = jnp.exp(head - m)  # [B,n,H,c]
    y_inter = jnp.einsum("bnchd,bnhc,bnhde->bnche", qc, inter_w, C_enter)
    n_inter = jnp.einsum("bnchd,bnhc,bnhd->bnch", qc, inter_w, N_enter)

    y = y_intra + y_inter
    denom = jnp.abs(n_intra.transpose(0, 1, 3, 2) + n_inter)  # [B,n,c,H]
    denom = jnp.maximum(denom, jnp.exp(-m.transpose(0, 1, 3, 2)))
    y = y / denom[..., None]
    return y.reshape(Bsz, S, H, hd).astype(q.dtype)


def mlstm_init_state(cfg: ArchConfig, batch: int, dtype) -> Dict:
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    H = max(cfg.num_heads, 1)
    hd = d_inner // H
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_inner), dtype)}


def mlstm_step(p: Params, u: jnp.ndarray, state: Dict, cfg: ArchConfig
               ) -> Tuple[jnp.ndarray, Dict]:
    """Single decode step with the standard stabilized recurrence."""
    Bsz = u.shape[0]
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    H = max(cfg.num_heads, 1)
    hd = d_inner // H
    xz = dense(p["up_proj"], u[:, 0, :])
    x, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = causal_conv1d_step(p["conv"], x, state["conv"])
    xc = jax.nn.silu(xc)
    q = dense(p["wq"], xc).reshape(Bsz, H, hd).astype(jnp.float32)
    k = (dense(p["wk"], xc).reshape(Bsz, H, hd) / jnp.sqrt(hd).astype(u.dtype)).astype(jnp.float32)
    v = dense(p["wv"], x).reshape(Bsz, H, hd).astype(jnp.float32)
    gates = (xc @ p["w_if"].astype(xc.dtype) + p["b_if"].astype(xc.dtype)).astype(jnp.float32)
    log_i, log_f = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    C = f_s[..., None, None] * state["C"] + i_s[..., None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    nrm = f_s[..., None] * state["n"] + i_s[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.sum(q * nrm, -1)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(Bsz, 1, d_inner).astype(u.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z[:, None, :])
    return dense(p["down_proj"], y), {"C": C, "n": nrm, "m": m_new, "conv": conv_state}


# ===========================================================================
# sLSTM (xLSTM scalar-memory block; strictly sequential recurrence)
# ===========================================================================


def slstm_init(fac: PFac, cfg: ArchConfig) -> Params:
    D = cfg.d_model
    H = max(cfg.num_heads, 1)
    hd = D // H
    ff = int(D * 4 / 3 / 64) * 64 or 64  # xLSTM post-up FFN (4/3 factor)
    return {
        "conv": causal_conv1d_init(fac, "conv", D, cfg.conv_kernel),
        "w": fac.param("w", (D, 4 * D), (None, "heads"), init="normal"),
        "r": fac.param("r", (H, hd, 4 * hd), (None, None, None), init="normal", fan_in=hd),
        "b": fac.param("b", (4 * D,), (None,), init="zeros"),
        "norm": rmsnorm_init(fac, "norm", D),
        "ff_up": dense_init(fac, "ff_up", D, ff, (None, "mlp")),
        "ff_down": dense_init(fac, "ff_down", ff, D, ("mlp", None)),
    }


def _slstm_cell(p: Params, wx_t: jnp.ndarray, state, H: int, hd: int):
    """wx_t: [B, 4D] precomputed input contribution."""
    c, nrm, h, m = state
    Bsz = wx_t.shape[0]
    rh = jnp.einsum("bhd,hde->bhe", h, p["r"].astype(jnp.float32)).reshape(Bsz, 4 * H * hd)
    pre = (wx_t + rh).reshape(Bsz, H, hd, 4)
    zi, ii, fi, oi = pre[..., 0], pre[..., 1], pre[..., 2], pre[..., 3]
    log_f = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(log_f + m, ii)
    i_s = jnp.exp(ii - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(zi)
    n_new = f_s * nrm + i_s
    h_new = jax.nn.sigmoid(oi) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(p: Params, u: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    Bsz, S, D = u.shape
    H = max(cfg.num_heads, 1)
    hd = D // H
    xc = jax.nn.silu(causal_conv1d(p["conv"], u))
    wx = (xc @ p["w"].astype(xc.dtype) + p["b"].astype(xc.dtype)).astype(jnp.float32)

    z0 = jnp.zeros((Bsz, H, hd), jnp.float32)
    state0 = (z0, z0, z0, jnp.full((Bsz, H, hd), -jnp.inf, jnp.float32))
    _, hs = jax.lax.scan(lambda s, w_t: _slstm_cell(p, w_t, s, H, hd),
                         state0, wx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(Bsz, S, D).astype(u.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return dense(p["ff_down"], jax.nn.gelu(dense(p["ff_up"], y)))


def slstm_init_state(cfg: ArchConfig, batch: int, dtype) -> Dict:
    D = cfg.d_model
    H = max(cfg.num_heads, 1)
    hd = D // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, H, hd), -jnp.inf, jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, D), dtype)}


def slstm_step(p: Params, u: jnp.ndarray, state: Dict, cfg: ArchConfig
               ) -> Tuple[jnp.ndarray, Dict]:
    Bsz = u.shape[0]
    D = cfg.d_model
    H = max(cfg.num_heads, 1)
    hd = D // H
    xc, conv_state = causal_conv1d_step(p["conv"], u[:, 0, :], state["conv"])
    xc = jax.nn.silu(xc)
    wx = (xc @ p["w"].astype(xc.dtype) + p["b"].astype(xc.dtype)).astype(jnp.float32)
    st = (state["c"], state["n"], state["h"], state["m"])
    (c, nrm, h, m), h_out = _slstm_cell(p, wx, st, H, hd)
    y = h_out.reshape(Bsz, 1, D).astype(u.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    y = dense(p["ff_down"], jax.nn.gelu(dense(p["ff_up"], y)))
    return y, {"c": c, "n": nrm, "h": h, "m": m, "conv": conv_state}
