"""CNNs for the faithful paper reproduction: ResNet10/18, VGG11_bn/VGG16_bn.

These mirror the paper's simulation testbed (CIFAR10/100). The model exposes
the same decomposed interface as the LM (stem / run_stages(lo,hi) / head) so
SmartFreeze's progressive trainer drives both. BatchNorm running stats live in
a separate ``state`` tree (FedAvg aggregates them like parameters, per paper).

Stage specs also drive the paper's output-module construction (core/
output_module.py): each remaining stage is emulated by one stride-matched
conv layer, preserving the trained block's "position" in the architecture.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import batchnorm, batchnorm_init, conv2d, conv2d_init
from repro.models.module import PFac, Params


@dataclass(frozen=True)
class CNNConfig:
    name: str
    kind: str  # resnet | vgg
    num_classes: int = 10
    # resnet: blocks per stage; vgg: convs per stage
    stage_sizes: Tuple[int, ...] = (2, 2, 2, 2)
    stage_channels: Tuple[int, ...] = (64, 128, 256, 512)
    in_channels: int = 3
    num_freeze_blocks: int = 4
    # BN running-stat momentum. 0.6, not torch's 0.9-equivalent: federated
    # rounds run only a handful of minibatches per client before Eq. 1
    # aggregation, and stats anchored at their (0, 1) init leave the
    # eval-mode forward degenerate for the whole short-horizon simulation.
    # Long centralized runs are insensitive to this choice.
    bn_momentum: float = 0.6

    def block_boundaries(self) -> Tuple[int, ...]:
        """SmartFreeze blocks == network stages (paper: ResNet-18 -> 4 blocks)."""
        n = len(self.stage_sizes)
        return tuple(range(n + 1))


RESNET10 = CNNConfig("resnet10", "resnet", stage_sizes=(1, 1, 1, 1))
RESNET18 = CNNConfig("resnet18", "resnet", stage_sizes=(2, 2, 2, 2))
VGG11 = CNNConfig("vgg11_bn", "vgg", stage_sizes=(1, 1, 2, 2, 2),
                  stage_channels=(64, 128, 256, 512, 512))
VGG16 = CNNConfig("vgg16_bn", "vgg", stage_sizes=(2, 2, 3, 3, 3),
                  stage_channels=(64, 128, 256, 512, 512))

CNN_REGISTRY = {c.name: c for c in (RESNET10, RESNET18, VGG11, VGG16)}


def softmax_xent(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy in fp32 — THE loss of the CNN testbed
    (model, stage trainers, and every baseline share this one copy)."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# ResNet pieces
# ---------------------------------------------------------------------------


def _basic_block_init(fac: PFac, c_in: int, c_out: int) -> Tuple[Params, Params]:
    p: Params = {}
    s: Params = {}
    p["conv1"] = conv2d_init(fac, "conv1", c_in, c_out, 3, bias=False)
    p["bn1"], s["bn1"] = batchnorm_init(fac, "bn1", c_out)
    p["conv2"] = conv2d_init(fac, "conv2", c_out, c_out, 3, bias=False)
    p["bn2"], s["bn2"] = batchnorm_init(fac, "bn2", c_out)
    if c_in != c_out:
        p["proj"] = conv2d_init(fac, "proj", c_in, c_out, 1, bias=False)
        p["bn_proj"], s["bn_proj"] = batchnorm_init(fac, "bn_proj", c_out)
    return p, s


def _basic_block(p: Params, s: Params, x: jnp.ndarray, stride: int, *,
                 train: bool, momentum: float = 0.6
                 ) -> Tuple[jnp.ndarray, Params]:
    ns: Params = {}
    h = conv2d(p["conv1"], x, stride=stride)
    h, ns["bn1"] = batchnorm(p["bn1"], s["bn1"], h, train=train,
                             momentum=momentum)
    h = jax.nn.relu(h)
    h = conv2d(p["conv2"], h)
    h, ns["bn2"] = batchnorm(p["bn2"], s["bn2"], h, train=train,
                             momentum=momentum)
    if "proj" in p:
        sc = conv2d(p["proj"], x, stride=stride)
        sc, ns["bn_proj"] = batchnorm(p["bn_proj"], s["bn_proj"], sc,
                                      train=train, momentum=momentum)
    else:
        sc = x if stride == 1 else x[:, ::stride, ::stride, :]
    return jax.nn.relu(h + sc), ns


# ---------------------------------------------------------------------------
# CNN model
# ---------------------------------------------------------------------------


@dataclass
class CNN:
    cfg: CNNConfig

    def init(self, rng) -> Tuple[Params, Params]:
        cfg = self.cfg
        fac = PFac(rng, dtype=jnp.float32)
        p: Params = {}
        s: Params = {}
        if cfg.kind == "resnet":
            sf = fac.sub("stem")
            bn_p, s["stem_bn"] = batchnorm_init(sf, "bn", cfg.stage_channels[0])
            p["stem"] = {"conv": conv2d_init(sf, "conv", cfg.in_channels,
                                             cfg.stage_channels[0], 3, bias=False),
                         "bn": bn_p}
        stages: Params = {}
        sstates: Params = {}
        c_prev = cfg.stage_channels[0] if cfg.kind == "resnet" else cfg.in_channels
        for i, (nb, ch) in enumerate(zip(cfg.stage_sizes, cfg.stage_channels)):
            sf = fac.sub(f"stage{i}")
            blocks: Params = {}
            bstates: Params = {}
            for j in range(nb):
                bf = sf.sub(f"b{j}")
                if cfg.kind == "resnet":
                    bp, bs = _basic_block_init(bf, c_prev if j == 0 else ch, ch)
                else:  # vgg: conv-bn-relu
                    bp = {"conv": conv2d_init(bf, "conv", c_prev if j == 0 else ch, ch, 3)}
                    bp["bn"], bs0 = batchnorm_init(bf, "bn", ch)
                    bs = {"bn": bs0}
                blocks[f"b{j}"] = bp
                bstates[f"b{j}"] = bs
            stages[f"stage{i}"] = blocks
            sstates[f"stage{i}"] = bstates
            c_prev = ch
        p["stages"] = stages
        s["stages"] = sstates
        p["fc"] = {"w": fac.param("fc_w", (cfg.stage_channels[-1], cfg.num_classes),
                                  (None, None), init="normal"),
                   "b": fac.param("fc_b", (cfg.num_classes,), (None,), init="zeros")}
        return p, s

    # ----- stage-decomposed forward -----

    def stem(self, params: Params, state: Params, x: jnp.ndarray, *, train: bool):
        if self.cfg.kind != "resnet":
            return x, state
        h = conv2d(params["stem"]["conv"], x)
        h, bn = batchnorm(params["stem"]["bn"], state["stem_bn"], h,
                          train=train, momentum=self.cfg.bn_momentum)
        new_state = dict(state)
        new_state["stem_bn"] = bn
        return jax.nn.relu(h), new_state

    def run_stages(self, params: Params, state: Params, h: jnp.ndarray,
                   lo: int, hi: int, *, train: bool):
        cfg = self.cfg
        new_state = {k: v for k, v in state.items()}
        new_stages = dict(state["stages"])
        for i in range(lo, hi):
            blocks = params["stages"][f"stage{i}"]
            bstates = state["stages"][f"stage{i}"]
            nbs: Params = {}
            for j in range(cfg.stage_sizes[i]):
                bp, bs = blocks[f"b{j}"], bstates[f"b{j}"]
                if cfg.kind == "resnet":
                    stride = 2 if (j == 0 and i > 0) else 1
                    h, ns = _basic_block(bp, bs, h, stride, train=train,
                                         momentum=cfg.bn_momentum)
                else:
                    h = conv2d(bp["conv"], h)
                    h, bn = batchnorm(bp["bn"], bs["bn"], h, train=train,
                                      momentum=cfg.bn_momentum)
                    h = jax.nn.relu(h)
                    ns = {"bn": bn}
                nbs[f"b{j}"] = ns
            if cfg.kind == "vgg":  # maxpool after each vgg stage
                h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                          (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            new_stages[f"stage{i}"] = nbs
        new_state["stages"] = new_stages
        return h, new_state

    def head(self, params: Params, h: jnp.ndarray) -> jnp.ndarray:
        h = jnp.mean(h, axis=(1, 2))  # global average pool
        return h @ params["fc"]["w"] + params["fc"]["b"]

    def apply(self, params: Params, state: Params, x: jnp.ndarray, *,
              train: bool = True):
        h, state = self.stem(params, state, x, train=train)
        h, state = self.run_stages(params, state, h, 0, len(self.cfg.stage_sizes),
                                   train=train)
        return self.head(params, h), state

    def loss(self, params: Params, state: Params, batch: Dict, *, train: bool = True):
        logits, new_state = self.apply(params, state, batch["x"], train=train)
        return softmax_xent(logits, batch["y"]), new_state

    def stage_output_channels(self, stage: int) -> int:
        return self.cfg.stage_channels[stage]


def build_cnn(name: str, num_classes: int = 10) -> CNN:
    import dataclasses

    cfg = dataclasses.replace(CNN_REGISTRY[name], num_classes=num_classes)
    return CNN(cfg)
