"""Attention: GQA (llama/qwen/grok/hubert/...) and MLA (deepseek-v2/minicpm3).

Two execution paths per flavour:
  * full-sequence (train / prefill): causal or bidirectional, fp32 softmax;
  * decode: one new token against a KV cache (GQA: grouped-head einsum with no
    kv repeat; MLA: matrix-absorbed latent attention — scores computed in the
    compressed kv_lora space so the cache stays tiny).

Logical sharding axes used here:
  "heads"  — q-head dim (→ "model" when divisible, else unsharded)
  "qkv_in" — d_model reduction dim of the projections (fallback TP axis)
  "kv"     — kv-head dim
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init
from repro.models.module import PFac, Params

NEG_INF = -1e9  # mask value (finite: avoids NaN rows for fully-masked queries)


def _shard_heads(x: jnp.ndarray, heads_dim: int = 2, *,
                 batch_axes=("pod", "data")) -> jnp.ndarray:
    """Constrain batch (dim0) + heads dims of attention intermediates.

    Without this, GQA with replicated kv (kv_heads < model axis) lets GSPMD
    pick *replicated* S×S attention scores — 100+ GB/device at 4k seq. The
    batch axes come from cfg.batch_axes so the constraint stays valid inside
    the federated vmap-over-pods (("data",) there — pod is consumed by vmap).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = list(mesh.axis_names)
        shape = dict(mesh.shape)
    except Exception:  # noqa: BLE001 — no ambient mesh (tests / CPU path)
        return x
    if not names:
        return x
    from jax.sharding import PartitionSpec as P

    spec = [None] * x.ndim
    baxes = tuple(a for a in batch_axes if a in names)
    if baxes:
        size = 1
        for a in baxes:
            size *= shape[a]
        if x.shape[0] % size == 0 and x.shape[0] >= size:
            spec[0] = baxes if len(baxes) > 1 else baxes[0]
    msize = shape.get("model", 0)
    H = x.shape[heads_dim]
    if msize and H % msize == 0 and H >= msize:
        spec[heads_dim] = "model"
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ===========================================================================
# GQA
# ===========================================================================


def gqa_init(fac: PFac, cfg: ArchConfig) -> Params:
    d, nq, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": dense_init(fac, "wq", d, nq * hd, ("qkv_in", "heads"), bias=cfg.qkv_bias),
        "wk": dense_init(fac, "wk", d, nkv * hd, ("qkv_in", "kv"), bias=cfg.qkv_bias),
        "wv": dense_init(fac, "wv", d, nkv * hd, ("qkv_in", "kv"), bias=cfg.qkv_bias),
        "wo": dense_init(fac, "wo", nq * hd, d, ("heads", "attn_out")),
    }


def _split_heads(x: jnp.ndarray, n: int) -> jnp.ndarray:
    return x.reshape(*x.shape[:-1], n, x.shape[-1] // n)


# ---------------------------------------------------------------------------
# Blockwise (online-softmax / flash-style) attention — pure JAX.
#
# Never materializes the S x S score matrix: outer lax.map over q blocks,
# inner lax.scan over kv blocks carrying (running max, denom, weighted acc).
# This is the XLA reference of kernels/flash_attention.py and the default
# full-sequence path for S >= ATTN_BLOCK_THRESHOLD (prefill_32k is infeasible
# without it). Causal masking is by absolute position; fully-masked kv blocks
# are computed-and-masked (structured skip belongs to the Pallas kernel).
# ---------------------------------------------------------------------------

ATTN_BLOCK_THRESHOLD = 2048
BLOCK_Q = 512
BLOCK_K = 1024


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool, scale: float,
                        block_q: int = BLOCK_Q, block_k: int = BLOCK_K
                        ) -> jnp.ndarray:
    """q: [B,S,H,dk]; k: [B,S,H,dk]; v: [B,S,H,dv] -> [B,S,H,dv]."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    bq = min(block_q, S)
    while S % bq:
        bq //= 2
    bk = min(block_k, S)
    while S % bk:
        bk //= 2
    nq, nk = S // bq, S // bk
    qb = q.reshape(B, nq, bq, H, dk).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(B, nk, bk, H, dk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, bk, H, dv).transpose(1, 0, 2, 3, 4)

    def one_q_block(args):
        qi, qblk = args  # qblk: [B, bq, H, dk]

        @jax.checkpoint
        def kv_step(carry, args2):
            m, l, acc = carry
            kj, kblk, vblk = args2
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            if causal:
                pos_q = qi * bq + jnp.arange(bq)
                pos_k = kj * bk + jnp.arange(bk)
                s = jnp.where(pos_q[:, None] >= pos_k[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qblk.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, H, bq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B, bq, H, dv]

    outs = jax.lax.map(one_q_block, (jnp.arange(nq), qb))  # [nq, B, bq, H, dv]
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv)


def gqa_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                positions: Optional[jnp.ndarray] = None,
                causal: bool = True) -> jnp.ndarray:
    """Full-sequence attention. x: [B, S, D] -> [B, S, D]."""
    B, S, _ = x.shape
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = _split_heads(dense(p["wq"], x), nq)
    k = _split_heads(dense(p["wk"], x), nkv)
    v = _split_heads(dense(p["wv"], x), nkv)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    g = nq // nkv
    # broadcast kv across the q-head group (repeat keeps the head dim = nq so
    # the "heads" sharding axis stays consistent through the whole layer)
    k = _shard_heads(jnp.repeat(k, g, axis=2), batch_axes=cfg.batch_axes)
    v = _shard_heads(jnp.repeat(v, g, axis=2), batch_axes=cfg.batch_axes)
    q = _shard_heads(q, batch_axes=cfg.batch_axes)
    scale = 1.0 / float(np.sqrt(hd))
    if getattr(cfg, "attention_impl", "xla") == "pallas":
        # kernels/flash_attention.py via its differentiable ops wrapper
        # (custom_vjp, recompute backward through the XLA reference). kv is
        # already repeated to nq heads above, so the kernel runs with
        # group size 1; interpret mode executes the body off-TPU.
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_attention(q, k, v, causal, scale)
    elif S >= ATTN_BLOCK_THRESHOLD:
        out = blockwise_attention(q, k, v, causal=causal, scale=scale)
    else:
        scores = jnp.einsum("bsnh,btnh->bnst", q, k).astype(jnp.float32) * scale
        scores = _shard_heads(scores, heads_dim=1, batch_axes=cfg.batch_axes)
        if causal:
            mask = jnp.tril(jnp.ones((S, S), bool))
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bnst,btnh->bsnh", probs, v)
    return dense(p["wo"], out.reshape(B, S, nq * hd))


def gqa_init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    nkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, max_seq, nkv, hd), dtype),
            "v": jnp.zeros((batch, max_seq, nkv, hd), dtype)}


def gqa_decode(p: Params, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
               cfg: ArchConfig) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. x: [B, 1, D]; pos: scalar index of the new token."""
    B = x.shape[0]
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = nq // nkv
    S = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(_split_heads(dense(p["wq"], x), nq), positions, cfg.rope_theta)
    k_new = apply_rope(_split_heads(dense(p["wk"], x), nkv), positions, cfg.rope_theta)
    v_new = _split_heads(dense(p["wv"], x), nkv)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
    qg = q.reshape(B, 1, nkv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) / jnp.sqrt(hd).astype(jnp.float32)
    valid = (jnp.arange(S) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v).reshape(B, 1, nq * hd)
    return dense(p["wo"], out), {"k": k, "v": v}


# ===========================================================================
# MLA (multi-head latent attention)
# ===========================================================================


def mla_init(fac: PFac, cfg: ArchConfig) -> Params:
    d, nh = cfg.d_model, cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    p: Params = {}
    if cfg.q_lora_rank > 0:
        p["wq_a"] = dense_init(fac, "wq_a", d, cfg.q_lora_rank, ("qkv_in", None))
        p["q_norm"] = rmsnorm_init(fac, "q_norm", cfg.q_lora_rank)
        p["wq_b"] = dense_init(fac, "wq_b", cfg.q_lora_rank, nh * (nope + rope_d), (None, "heads"))
    else:
        p["wq"] = dense_init(fac, "wq", d, nh * (nope + rope_d), ("qkv_in", "heads"))
    p["wkv_a"] = dense_init(fac, "wkv_a", d, cfg.kv_lora_rank + rope_d, ("qkv_in", None))
    p["kv_norm"] = rmsnorm_init(fac, "kv_norm", cfg.kv_lora_rank)
    p["wkv_b"] = dense_init(fac, "wkv_b", cfg.kv_lora_rank, nh * (nope + vd), (None, "heads"))
    p["wo"] = dense_init(fac, "wo", nh * vd, d, ("heads", "attn_out"))
    return p


def _mla_q(p: Params, x: jnp.ndarray, cfg: ArchConfig, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    nh, nope, rope_d = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank > 0:
        q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x), cfg.norm_eps))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(*x.shape[:-1], nh, nope + rope_d)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _mla_kv_latent(p: Params, x: jnp.ndarray, cfg: ArchConfig, positions):
    """Compressed cache entries: normed c_kv and roped shared k_pe."""
    kv_a = dense(p["wkv_a"], x)
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., :cfg.kv_lora_rank], cfg.norm_eps)
    k_pe = kv_a[..., cfg.kv_lora_rank:]  # [B, S, rope_d] single shared head
    k_pe = apply_rope(k_pe[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_pe


def mla_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                positions: Optional[jnp.ndarray] = None,
                causal: bool = True) -> jnp.ndarray:
    """Full-sequence MLA with explicit k/v expansion (cheaper than absorption
    when S tokens each attend to S keys: score dim nope+rope << kv_lora)."""
    B, S, _ = x.shape
    nh, nope, rope_d, vd = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_pe = _mla_q(p, x, cfg, positions)
    c_kv, k_pe = _mla_kv_latent(p, x, cfg, positions)
    kv = dense(p["wkv_b"], c_kv).reshape(B, S, nh, nope + vd)
    kv = _shard_heads(kv, batch_axes=cfg.batch_axes)
    q_nope = _shard_heads(q_nope, batch_axes=cfg.batch_axes)
    q_pe = _shard_heads(q_pe, batch_axes=cfg.batch_axes)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    scale = 1.0 / float(np.sqrt(nope + rope_d))
    if S >= ATTN_BLOCK_THRESHOLD:
        # fold the shared rope head into the per-head k so MLA reuses the
        # same blockwise primitive: q' = [q_nope | q_pe], k' = [k_nope | k_pe]
        k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :], (B, S, nh, rope_d))
        q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
        k_full = jnp.concatenate([k_nope, k_pe_b], axis=-1)
        out = blockwise_attention(q_full, k_full, v, causal=causal, scale=scale)
    else:
        scores = (jnp.einsum("bsnh,btnh->bnst", q_nope, k_nope)
                  + jnp.einsum("bsnh,bth->bnst", q_pe, k_pe)).astype(jnp.float32) * scale
        scores = _shard_heads(scores, heads_dim=1, batch_axes=cfg.batch_axes)
        if causal:
            mask = jnp.tril(jnp.ones((S, S), bool))
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bnst,btnh->bsnh", probs, v)
    return dense(p["wo"], out.reshape(B, S, nh * vd))


def mla_init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    return {"ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype)}


def mla_decode(p: Params, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
               cfg: ArchConfig) -> Tuple[jnp.ndarray, Dict]:
    """Matrix-absorbed decode: attention runs in the kv_lora latent space, so
    per-step cost is O(S * (kv_lora + rope_d)) per head and the cache holds
    only the compressed latents."""
    B = x.shape[0]
    nh, nope, rope_d, vd = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_pe = _mla_q(p, x, cfg, positions)  # [B,1,nh,nope],[B,1,nh,rope]
    c_new, kpe_new = _mla_kv_latent(p, x, cfg, positions)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_new.astype(cache["ckv"].dtype), (0, pos, 0))
    kpe = jax.lax.dynamic_update_slice(cache["kpe"], kpe_new.astype(cache["kpe"].dtype), (0, pos, 0))
    S = ckv.shape[1]
    wkv_b = p["wkv_b"]["w"].reshape(lora, nh, nope + vd).astype(x.dtype)
    wk_b, wv_b = wkv_b[..., :nope], wkv_b[..., nope:]
    # absorb k projection into q: q_lat [B,1,nh,lora]
    q_lat = jnp.einsum("bqnd,lnd->bqnl", q_nope, wk_b)
    scale = 1.0 / jnp.sqrt(jnp.float32(nope + rope_d))
    scores = (jnp.einsum("bqnl,bsl->bnqs", q_lat, ckv)
              + jnp.einsum("bqnh,bsh->bnqs", q_pe, kpe)).astype(jnp.float32) * scale
    valid = (jnp.arange(S) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bnqs,bsl->bqnl", probs, ckv)
    out = jnp.einsum("bqnl,lnd->bqnd", out_lat, wv_b).reshape(B, 1, nh * vd)
    return dense(p["wo"], out), {"ckv": ckv, "kpe": kpe}


# ===========================================================================
# Dispatch helpers
# ===========================================================================


def attn_init(fac: PFac, cfg: ArchConfig) -> Params:
    return mla_init(fac, cfg) if cfg.attention == "mla" else gqa_init(fac, cfg)


def attn_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig, **kw) -> jnp.ndarray:
    fn = mla_forward if cfg.attention == "mla" else gqa_forward
    return fn(p, x, cfg, **kw)


def attn_init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    fn = mla_init_cache if cfg.attention == "mla" else gqa_init_cache
    return fn(cfg, batch, max_seq, dtype)


def attn_decode(p: Params, x: jnp.ndarray, cache: Dict, pos, cfg: ArchConfig):
    fn = mla_decode if cfg.attention == "mla" else gqa_decode
    return fn(p, x, cache, pos, cfg)
