"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone + shared attention blocks.

Assumption (DESIGN.md): 81 layers with every 6th a shared-attention block
(2 alternating tied weight sets), rest Mamba2 (state=64). The HF checkpoint's
concat-with-embedding input and per-occurrence LoRA on the shared blocks are
simplified away (noted in DESIGN.md hardware-adaptation table)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_expand=2, attn_every=6, num_shared_attn_sets=2,
    subquadratic=True, num_freeze_blocks=6,
))
