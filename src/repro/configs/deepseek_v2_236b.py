"""DeepSeek-V2 236B [arXiv:2405.04434; hf]: MLA kv_lora=512, MoE 160e top-6,
2 shared experts, first layer dense. Expert-parallel sharding (160/16=10/chip).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400, head_dim=128,
    attention="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    num_experts=160, num_shared_experts=2, experts_per_token=6,
    moe_d_ff=1536, moe_sharding="ep", first_dense_layers=1,
    num_freeze_blocks=6,
))
