"""HuBERT-XLarge [arXiv:2106.07447]: encoder-only (bidirectional), conv
feature extractor STUB (input_specs provides 512-dim frame features),
masked-cluster prediction head over 504 units. No decode shapes."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, head_dim=80,
    is_encoder_only=True, modality="audio_stub", frontend_dim=512,
    norm="layernorm", mlp_activation="gelu", num_freeze_blocks=4,
))
