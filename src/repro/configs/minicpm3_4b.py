"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: dense MLA. 40 heads do not divide the
16-way model axis -> reduction-dim TP fallback (DESIGN.md §4); vocab 73448 is
odd too, so the embedding shards on d_model."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=6400, vocab_size=73448, head_dim=64,
    attention="mla", q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    num_freeze_blocks=6,
))
