"""Paper-repro CNNs (VGG11_bn/VGG16_bn on CIFAR) — see models/cnn.py."""
from repro.models.cnn import VGG11, VGG16  # noqa: F401
