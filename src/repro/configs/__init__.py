from repro.configs.base import (ArchConfig, ShapeConfig, SHAPES, TRAIN_4K,
                                PREFILL_32K, DECODE_32K, LONG_500K, get, names,
                                register, shapes_for, skip_reason)
