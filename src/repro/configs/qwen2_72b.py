"""Qwen2-72B [arXiv:2407.10671; hf]: GQA kv=8 with QKV bias."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-72b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1000000.0, num_freeze_blocks=8,
))
