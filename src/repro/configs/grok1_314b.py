"""Grok-1 314B [hf:xai-org/grok-1]: 64L d=6144 48H GQA kv=8, MoE 8e top-2,
d_ff=32768. Expert tensor-parallel sharding (32768/16=2048 per shard)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072, head_dim=128,
    num_experts=8, num_shared_experts=0, experts_per_token=2,
    moe_d_ff=32768, moe_sharding="tp",
    mlp_activation="gelu", num_freeze_blocks=8,
))
