"""InternVL2-2B [arXiv:2404.16821; hf]: InternViT frontend (STUB — input_specs
provides precomputed patch embeddings, vit_dim=1024, 256 tokens/image) +
InternLM2-1.8B backbone (GQA kv=8)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, head_dim=128,
    modality="vision_stub", frontend_dim=1024, num_image_tokens=256,
    num_freeze_blocks=4,
))
