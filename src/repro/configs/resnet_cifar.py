"""Paper-repro CNNs (ResNet10/18 on CIFAR) — see models/cnn.py."""
from repro.models.cnn import RESNET10, RESNET18  # noqa: F401
