"""DeepSeek-Coder-33B [arXiv:2401.14196; hf]: llama-arch GQA kv=8.
56 heads do not divide the 16-way model axis -> reduction-dim TP fallback."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, head_dim=128,
    rope_theta=100000.0, num_freeze_blocks=6,
))
