"""Llama-3-8B [arXiv:2407.21783]: GQA kv=8, 128k vocab, rope theta 500k."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    rope_theta=500000.0, num_freeze_blocks=4,
))
