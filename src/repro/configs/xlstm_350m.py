"""xLSTM-350M [arXiv:2405.04517]: sLSTM + mLSTM blocks, 24L d=1024 4H.

Assumption (noted in DESIGN.md): xLSTM[7:1] ratio -> every 8th layer sLSTM,
rest mLSTM (the paper's 350M variant interleaves both block types).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    ssm_state=64, ssm_expand=2, slstm_every=8,
    subquadratic=True, num_freeze_blocks=4,
))
