"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every assigned input
shape is a ``ShapeConfig``. The registry maps ``--arch <id>`` to its config and
``reduced()`` derives the CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shape configs (assigned input-shape set; LM shapes are seq_len x batch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention flavour ---
    attention: str = "gqa"  # gqa | mla
    # full-sequence execution path: "xla" = dense einsum / blockwise online
    # softmax (models/attention.py); "pallas" = kernels/flash_attention.py
    # via kernels.ops (interpret-mode off-TPU). GQA only; opt-in via
    # launch/train.py --use-pallas.
    attention_impl: str = "xla"
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    # --- MLA (deepseek-v2 / minicpm3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_impl: str = "gshard"  # gshard | a2a
    moe_sharding: str = "ep"  # ep (expert-parallel) | tp (expert tensor-parallel)
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # leading layers that use the dense MLP

    # --- SSM / recurrent ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    slstm_every: int = 0  # xlstm: every k-th layer is sLSTM (0 = none)

    # --- hybrid (zamba2) ---
    attn_every: int = 0  # every k-th layer is a (shared) attention layer
    num_shared_attn_sets: int = 0  # weight-tied attention block sets

    # --- encoder-only / modality ---
    is_encoder_only: bool = False
    modality: str = "text"  # text | vision_stub | audio_stub
    frontend_dim: int = 0  # stub feature dim (vision/audio)
    num_image_tokens: int = 0  # vlm: vision tokens prepended per sequence

    # --- activation / misc ---
    mlp_activation: str = "silu"  # silu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- SmartFreeze / progressive training ---
    num_freeze_blocks: int = 4

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- distribution context (threaded by the launcher; ("data",) inside the
    # federated vmap-over-pods where the pod axis is already consumed) ---
    batch_axes: tuple = ("pod", "data")

    # --- capability flags ---
    subquadratic: bool = False  # True for SSM/hybrid: long_500k is runnable

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # ----- derived properties -----

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_heads_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string, length num_layers."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":  # xlstm
                if self.slstm_every and (i % self.slstm_every) == (self.slstm_every - 1):
                    kinds.append("slstm")
                else:
                    kinds.append("mlstm")
            elif self.family == "hybrid":  # zamba2
                if self.attn_every and (i % self.attn_every) == (self.attn_every - 1):
                    kinds.append("shared_attn")
                else:
                    kinds.append("mamba2")
            elif self.is_moe:
                if i < self.first_dense_layers:
                    kinds.append("attn_mlp")
                else:
                    kinds.append("attn_moe")
            else:
                kinds.append("attn_mlp")
        return tuple(kinds)

    def segments(self) -> Tuple[Tuple[str, int], ...]:
        """Contiguous homogeneous (kind, count) runs — each run is one scan."""
        kinds = self.layer_kinds()
        segs = []
        for k in kinds:
            if segs and segs[-1][0] == k:
                segs[-1][1] += 1
            else:
                segs.append([k, 1])
        return tuple((k, n) for k, n in segs)

    def block_boundaries(self) -> Tuple[int, ...]:
        """Layer-index boundaries of the num_freeze_blocks SmartFreeze blocks.

        Returns (b_0=0, b_1, ..., b_T=num_layers): block t spans
        [boundaries[t], boundaries[t+1]).
        """
        T = self.num_freeze_blocks
        L = self.num_layers
        base, rem = divmod(L, T)
        sizes = [base + (1 if i < rem else 0) for i in range(T)]
        bounds = [0]
        for s in sizes:
            bounds.append(bounds[-1] + s)
        return tuple(bounds)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND MODEL_FLOPS and memory model)."""
        from repro.core.memory_model import arch_param_count

        return arch_param_count(self)

    def active_param_count(self) -> int:
        from repro.core.memory_model import arch_active_param_count

        return arch_active_param_count(self)

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=max(4, min(self.num_layers, 4)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
        )
        if self.attention == "mla":
            small.update(q_lora_rank=32 if self.q_lora_rank else 0, kv_lora_rank=32,
                         qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        if self.is_moe:
            small.update(num_experts=4, experts_per_token=2, moe_d_ff=64,
                         num_shared_experts=min(self.num_shared_experts, 1),
                         first_dense_layers=min(self.first_dense_layers, 1))
        if self.ssm_state:
            small.update(ssm_state=16, ssm_head_dim=16)
        if self.attn_every:
            small.update(attn_every=2)
        if self.slstm_every:
            small.update(slstm_every=4)
        if self.modality == "vision_stub":
            small.update(frontend_dim=32, num_image_tokens=8)
        if self.modality == "audio_stub":
            small.update(frontend_dim=32)
        small.update(num_freeze_blocks=min(self.num_freeze_blocks, 2),
                     name=self.name + "-reduced")
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def names() -> list:
    _load_all()
    return sorted(_REGISTRY.keys())


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import every config module for its registration side effect
    from repro.configs import (  # noqa: F401
        xlstm_350m, deepseek_v2_236b, grok1_314b, minicpm3_4b, llama3_8b,
        qwen2_72b, deepseek_coder_33b, internvl2_2b, hubert_xlarge, zamba2_7b,
        resnet_cifar, vgg_cifar,
    )


def shapes_for(cfg: ArchConfig) -> list:
    """The assigned shapes this arch actually runs (skips per DESIGN.md §5)."""
    out = [TRAIN_4K, PREFILL_32K]
    if not cfg.is_encoder_only:
        out.append(DECODE_32K)
        if cfg.subquadratic:
            out.append(LONG_500K)
    return out


def skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    if cfg.is_encoder_only and shape.kind == "decode":
        return "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "pure full-attention arch: long_500k skipped per assignment"
    return None
