"""Offline fallback for the tiny slice of ``hypothesis`` the test suite uses.

The real package is declared in pyproject.toml and is preferred whenever it
is importable; this stub only exists so the property tests still RUN (as
deterministic seeded sweeps) in hermetic environments without network
access. ``tests/conftest.py`` registers it under ``sys.modules`` when
``import hypothesis`` fails.

Supported surface: ``@settings(max_examples=..., deadline=...)``,
``@given(**strategies)`` with all test parameters supplied by strategies,
and ``strategies.sampled_from / integers / booleans``.
"""
from __future__ import annotations

import random
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_at(self, rng: random.Random):
        return self._draw(rng)


def _sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng: options[rng.randrange(len(options))])


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def _floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


strategies = types.ModuleType("hypothesis.strategies")
strategies.sampled_from = _sampled_from
strategies.integers = _integers
strategies.booleans = _booleans
strategies.floats = _floats

st = strategies  # common import alias


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    """Deterministic sweep: draw ``max_examples`` seeded examples and call
    the test once per draw. The wrapper takes no parameters, so pytest does
    not mistake strategy names for fixtures (matches how these tests use
    hypothesis: every argument comes from a strategy)."""

    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_hyp_max_examples", 20)
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                fn(**{k: s.example_at(rng) for k, s in strats.items()})

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hyp_max_examples = getattr(fn, "_hyp_max_examples", 20)
        return wrapper

    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
