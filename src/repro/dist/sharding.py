"""Logical-axis -> mesh sharding rules (GSPMD partition specs).

Every parameter records a tuple of *logical* axis names at init time
(``PFac.param``); this module maps those names onto physical mesh axes.
``make_rules`` applies the per-arch divisibility fallbacks:

  heads   -> "model" when num_heads divides the model-axis size, else the
             qkv INPUT dim ("qkv_in") takes the shard (minicpm3's 40 heads)
  vocab   -> "model" when vocab_size divides, else the embedding shards on
             d_model ("embed") instead (minicpm3's 73448-row table)
  expert  -> "model" for expert-parallel MoE (deepseek-v2: 160/16); archs
             whose expert count cannot divide (grok-1: 8 experts) fall back
             to expert tensor-parallel over "moe_ff"

``logical_to_spec`` turns one axes-tuple into a ``PartitionSpec``, never
reusing a mesh axis within a single spec (first dim wins).  ``shard_batch``
is the activation-side constraint used by model forwards; it is a no-op
when no mesh is active (CPU tests) or when none of the requested batch axes
exist on the current mesh.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

Rules = Dict[str, Optional[str]]

#: every logical axis name recorded by PFac across the model zoo
LOGICAL_AXES = ("embed", "vocab", "heads", "kv", "qkv_in", "attn_out",
                "mlp", "moe_ff", "expert")


def _axis_size(mesh, name: str) -> int:
    shape = getattr(mesh, "shape", {})
    try:
        return int(shape.get(name, 1))
    except AttributeError:  # Mesh.shape is a mapping in every supported jax
        return 1


def make_rules(cfg, mesh, *, no_tp: bool = False) -> Rules:
    """Map logical axis names -> mesh axis name (or None = replicate)."""
    rules: Rules = {name: None for name in LOGICAL_AXES}
    m = _axis_size(mesh, "model")
    if no_tp or m <= 1:
        return rules

    # attention: shard heads when divisible, else shard the qkv input dim
    if cfg.num_heads % m == 0:
        rules["heads"] = "model"
    elif cfg.d_model % m == 0:
        rules["qkv_in"] = "model"
    if cfg.num_kv_heads and cfg.num_kv_heads % m == 0:
        rules["kv"] = "model"

    # embedding/head: vocab shard when divisible, else d_model shard
    if cfg.vocab_size % m == 0:
        rules["vocab"] = "model"
    elif cfg.d_model % m == 0:
        rules["embed"] = "model"

    # dense MLP hidden
    if cfg.d_ff and cfg.d_ff % m == 0:
        rules["mlp"] = "model"

    # MoE: expert-parallel when the expert count divides, else expert-TP
    if getattr(cfg, "num_experts", 0):
        if cfg.moe_sharding == "ep" and cfg.num_experts % m == 0:
            rules["expert"] = "model"
        elif cfg.moe_d_ff % m == 0:
            rules["moe_ff"] = "model"
    return rules


def logical_to_spec(axes: Tuple[Optional[str], ...], rules: Rules,
                    shape: Optional[Tuple[int, ...]] = None) -> P:
    """PartitionSpec for one leaf. A mesh axis is used at most once per spec
    (the first logical dim mapping to it wins; later dims replicate).

    ``shape`` is accepted for signature stability but intentionally unused:
    all divisibility decisions are resolved ONCE per arch in ``make_rules``
    (which knows the mesh axis sizes); per-leaf spec construction is purely
    name-based."""
    used = set()
    out = []
    for i, name in enumerate(axes):
        mesh_axis = rules.get(name) if name is not None else None
        if mesh_axis is not None and mesh_axis in used:
            mesh_axis = None
        if mesh_axis is not None:
            used.add(mesh_axis)
        out.append(mesh_axis)
    return P(*out)


# ---------------------------------------------------------------------------
# Tree-level shardings (dry-run / launcher)
# ---------------------------------------------------------------------------


def tree_shardings(mesh, axes_tree, rules: Rules, aparams):
    """TP-only NamedShardings mirroring the param tree."""
    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def one(axes, leaf):
        return NamedSharding(mesh, logical_to_spec(axes, rules, leaf.shape))

    return jax.tree.map(one, axes_tree, aparams, is_leaf=is_axes_leaf)


def fsdp_tree_shardings(mesh, axes_tree, rules: Rules, aparams, *,
                        fsdp_axes: Tuple[str, ...] = ("data",),
                        output_dim_only: bool = False):
    """TP specs plus FSDP: shard the largest still-replicated dim of each
    leaf over ``fsdp_axes`` when divisible. ``output_dim_only`` restricts
    FSDP to the last (output) dim — avoids sharding contracting dims."""
    fsdp = tuple(a for a in fsdp_axes if _axis_size(mesh, a) > 1)
    n_fsdp = int(np.prod([_axis_size(mesh, a) for a in fsdp])) if fsdp else 1
    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def one(axes, leaf):
        spec = list(logical_to_spec(axes, rules, leaf.shape))
        spec += [None] * (len(leaf.shape) - len(spec))
        if fsdp and n_fsdp > 1:
            cands = range(len(leaf.shape) - 1, len(leaf.shape)) \
                if output_dim_only else range(len(leaf.shape))
            best = None
            for d in cands:
                if spec[d] is None and leaf.shape[d] % n_fsdp == 0 \
                        and leaf.shape[d] >= n_fsdp:
                    if best is None or leaf.shape[d] > leaf.shape[best]:
                        best = d
            if best is not None:
                spec[best] = fsdp if len(fsdp) > 1 else fsdp[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, axes_tree, aparams, is_leaf=is_axes_leaf)


def batch_spec(mesh, nd: int) -> NamedSharding:
    """Leading-dim data-parallel sharding over whatever dp axes exist."""
    dp = tuple(a for a in ("pod", "data") if _axis_size(mesh, a) > 1)
    lead = dp if len(dp) > 1 else (dp[0] if dp else None)
    return NamedSharding(mesh, P(*((lead,) + (None,) * (nd - 1))))


# ---------------------------------------------------------------------------
# Client-axis (federated cohort) sharding — fl/engine.py's shard_map path
# ---------------------------------------------------------------------------

#: mesh axis name for the federated cohort dimension (launch/mesh.py's
#: ``make_client_mesh``); the fused round engine shard_maps over it
CLIENT_AXIS = "clients"


def client_axis_size(mesh) -> int:
    """Size of the cohort axis on ``mesh`` (1 when absent or no mesh)."""
    return 1 if mesh is None else _axis_size(mesh, CLIENT_AXIS)


def client_spec(nd: int) -> P:
    """PartitionSpec sharding the leading (client) dim of an ``nd``-rank
    array, everything else replicated."""
    return P(*((CLIENT_AXIS,) + (None,) * (nd - 1)))


def shard_cohort(mesh, tree):
    """device_put a stacked-cohort pytree (leading dim = clients, already
    padded by the caller to a multiple of the client-axis size) partitioned
    along the client axis."""
    return jax.tree.map(
        lambda x: jax.device_put(
            x, NamedSharding(mesh, client_spec(np.ndim(x)))), tree)


def replicate(mesh, tree):
    """device_put a pytree fully replicated over ``mesh`` (round-start
    params / frozen prefix / BN state in the sharded round)."""
    return jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P())), tree)


def shard_client_arrays(mesh, tree):
    """Place per-client [N, ...] arrays (``ClientPopulation`` columns,
    ``FleetTimeModel`` columns, error-feedback pools) along the client axis.

    Same divisibility discipline as ``make_rules``: a leaf whose leading dim
    does not divide the client-axis size is REPLICATED instead of sharded —
    still correct, just not distributed. Identity when no client axis is
    active (CPU tests, single device)."""
    m = client_axis_size(mesh)
    if m <= 1:
        return tree

    def put(x):
        nd = np.ndim(x)
        if nd >= 1 and np.shape(x)[0] % m == 0:
            return jax.device_put(x, NamedSharding(mesh, client_spec(nd)))
        return jax.device_put(x, NamedSharding(mesh, P()))

    return jax.tree.map(put, tree)


# ---------------------------------------------------------------------------
# Activation-side constraint
# ---------------------------------------------------------------------------


def _current_mesh():
    try:  # jax >= 0.4.x thread-local physical mesh (set by `with mesh:`)
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # noqa: BLE001 — any jax-internal change means "no mesh"
        return None


def shard_batch(x, *, batch_axes: Tuple[str, ...] = ("pod", "data")):
    """Constrain an activation's leading (batch) dim over the dp axes of the
    active mesh. Identity on CPU tests / whenever no mesh is installed."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    axes = tuple(a for a in batch_axes if _axis_size(mesh, a) > 1)
    if not axes or x.ndim == 0 or x.shape[0] % int(
            np.prod([_axis_size(mesh, a) for a in axes])) != 0:
        return x
    lead = axes if len(axes) > 1 else axes[0]
    spec = P(*((lead,) + (None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
