from repro.dist.sharding import (CLIENT_AXIS, batch_spec, client_axis_size,
                                 client_spec, fsdp_tree_shardings,
                                 logical_to_spec, make_rules, replicate,
                                 shard_batch, shard_client_arrays,
                                 shard_cohort, tree_shardings)
