from repro.dist.sharding import (batch_spec, fsdp_tree_shardings,
                                 logical_to_spec, make_rules, shard_batch,
                                 tree_shardings)
