"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only: the
kernels execute their bodies in Python via the Pallas interpreter for
correctness validation; on a TPU backend they compile to Mosaic).

``flash_attention`` is differentiable: custom_vjp whose backward recomputes
through the XLA blockwise reference (O(S) memory, exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import block_perturb, decode_attention as dec, flash_attention as fa
from repro.kernels import dequant_matmul as dqmm
from repro.kernels import sparse_agg
from repro.kernels import ssm_scan as ssd
from repro.kernels import ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----- flash attention (differentiable) -----


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal=True, scale=None):
    return fa.flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                                  interpret=_default_interpret())


def _fa_fwd(q, k, v, causal, scale):
    return flash_attention(q, k, v, causal, scale), (q, k, v)


def _fa_bwd(causal, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.flash_attention_ref(
        q_, k_, v_, causal=causal, scale=scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ----- flash decode -----


@jax.jit
def flash_decode(q, k, v, length):
    return dec.decode_attention(q, k, v, length,
                                interpret=_default_interpret())


# ----- ssd scan -----


@jax.jit
def ssd_scan(x, dt, log_a, Bm, Cm):
    return ssd.ssd_scan(x, dt, log_a, Bm, Cm,
                        interpret=_default_interpret())


# ----- block perturbation reductions -----


def update_sqnorm(tree_new, tree_old):
    """On-mesh half of the pace controller: fused ||new - old||^2."""
    return block_perturb.tree_diff_sqnorm(tree_new, tree_old,
                                          interpret=_default_interpret())


# ----- fused int8-dequant matmul (differentiable wrt scale and w) -----


def dequant_matmul(q, scale, w, *, block_m=256, block_n=256, block_k=256,
                   out_dtype=jnp.float32, interpret=None):
    """``(q.astype(f32) * scale) @ w`` with the per-(sample, channel) scales
    applied in-register inside the GEMM (kernels/dequant_matmul.py).

    ``q`` is cache DATA (int8 tier values) and is non-differentiable; the
    custom_vjp carries gradients for ``scale`` and ``w`` by differentiating
    the XLA reference (exact — same convention as ``flash_attention``'s
    recompute backward). ``interpret=None`` -> container-aware default
    (True off-TPU)."""
    interpret = _default_interpret() if interpret is None else interpret

    @jax.custom_vjp
    def _fn(scale_, w_):
        return dqmm.dequant_matmul_fwd(
            q, scale_, w_, block_m=block_m, block_n=block_n, block_k=block_k,
            out_dtype=out_dtype, interpret=interpret)

    def _fwd(scale_, w_):
        return _fn(scale_, w_), (scale_, w_)

    def _bwd(res, g):
        scale_, w_ = res
        _, vjp = jax.vjp(
            lambda s_, w2: ref.dequant_matmul_ref(q, s_, w2,
                                                  out_dtype=out_dtype),
            scale_, w_)
        return vjp(g)

    _fn.defvjp(_fwd, _bwd)
    return _fn(scale, w)


# ----- sparse cohort scatter-add (compressed-uplink Eq. 1 fold) -----


def sparse_cohort_add(idx, vals, weights, length, *, interpret=None):
    """One-kernel dense [length] fold of K clients' top-k (idx, vals) rows
    (kernels/sparse_agg.py). Dispatch rule: leaves whose dense block exceeds
    ``sparse_agg.MAX_VMEM_ELEMS`` fall back to the XLA scatter reference —
    the kernel keeps the whole dense output VMEM-resident, so it is only
    selected when that residency is possible."""
    if length > sparse_agg.MAX_VMEM_ELEMS:
        return ref.sparse_cohort_add_ref(idx, vals, weights, length)
    interpret = _default_interpret() if interpret is None else interpret
    return sparse_agg.sparse_cohort_add_fwd(idx, vals, weights, length,
                                            interpret=interpret)


# ----- int8 feature-cache quantization (reference entry) -----
# Per-(sample, channel) symmetric int8 for the frozen-prefix activation
# cache. No Pallas body: the op is an abs-max reduce + a broadcast multiply
# XLA already fuses into the consumer on every backend, so the jitted jnp
# form IS the kernel. Implementation lives in repro.fl.quant (imported
# lazily — kernels/ stays import-independent of fl/).


def quantize_int8(x):
    """(q int8, scale f32) — see ``repro.fl.quant.quantize_int8``."""
    from repro.fl.quant import quantize_int8 as impl
    return impl(x)


def dequantize_int8(q, scale):
    """Fused dequant — see ``repro.fl.quant.dequantize_int8``."""
    from repro.fl.quant import dequantize_int8 as impl
    return impl(q, scale)
