"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only: the
kernels execute their bodies in Python via the Pallas interpreter for
correctness validation; on a TPU backend they compile to Mosaic).

``flash_attention`` is differentiable: custom_vjp whose backward recomputes
through the XLA blockwise reference (O(S) memory, exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import block_perturb, decode_attention as dec, flash_attention as fa
from repro.kernels import ssm_scan as ssd
from repro.kernels import ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----- flash attention (differentiable) -----


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal=True, scale=None):
    return fa.flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                                  interpret=_default_interpret())


def _fa_fwd(q, k, v, causal, scale):
    return flash_attention(q, k, v, causal, scale), (q, k, v)


def _fa_bwd(causal, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.flash_attention_ref(
        q_, k_, v_, causal=causal, scale=scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ----- flash decode -----


@jax.jit
def flash_decode(q, k, v, length):
    return dec.decode_attention(q, k, v, length,
                                interpret=_default_interpret())


# ----- ssd scan -----


@jax.jit
def ssd_scan(x, dt, log_a, Bm, Cm):
    return ssd.ssd_scan(x, dt, log_a, Bm, Cm,
                        interpret=_default_interpret())


# ----- block perturbation reductions -----


def update_sqnorm(tree_new, tree_old):
    """On-mesh half of the pace controller: fused ||new - old||^2."""
    return block_perturb.tree_diff_sqnorm(tree_new, tree_old,
                                          interpret=_default_interpret())


# ----- int8 feature-cache quantization (reference entry) -----
# Per-(sample, channel) symmetric int8 for the frozen-prefix activation
# cache. No Pallas body: the op is an abs-max reduce + a broadcast multiply
# XLA already fuses into the consumer on every backend, so the jitted jnp
# form IS the kernel. Implementation lives in repro.fl.quant (imported
# lazily — kernels/ stays import-independent of fl/).


def quantize_int8(x):
    """(q int8, scale f32) — see ``repro.fl.quant.quantize_int8``."""
    from repro.fl.quant import quantize_int8 as impl
    return impl(x)


def dequantize_int8(q, scale):
    """Fused dequant — see ``repro.fl.quant.dequantize_int8``."""
    from repro.fl.quant import dequantize_int8 as impl
    return impl(q, scale)
