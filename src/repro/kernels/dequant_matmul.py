"""Fused int8-dequant -> matmul — Pallas TPU kernel.

The int8 frozen-prefix cache tier (fl/quant.py) stores features as int8
values plus per-(sample, channel) f32 scales. The XLA path dequantizes by a
broadcast multiply the compiler fuses into the consumer; this kernel goes
one step further and applies the scales IN-REGISTER inside the GEMM inner
loop, so the f32 feature tile exists only as a VMEM-resident [bm, bk] block
and the dense f32 feature tensor is never written anywhere — the memory
contract the SmartFreeze tier ladder prices (core/memory_model.py).

Grid: (M/bm, N/bn, K/bk) with the contraction dim minor-most, so the f32
VMEM accumulator persists across the k loop (same scratch-across-grid
convention as flash_attention.py). Each step widens the int8 q tile to f32,
multiplies the scale tile in, and feeds the MXU via ``lax.dot_general`` with
``preferred_element_type=f32``.

Scale layouts (static ``scale_kind``) mirror fl/quant._group_axes:

  "row"  scale [M, 1] — 2-D feature rows (per-sample scale), the shape
         ``quantize_int8`` emits for flattened [N, D] features;
  "col"  scale [1, K] — per-input-channel scales (weight-style layouts);
  "full" scale [M, K] — dense per-element scales (already-materialized
         broadcast products; also the padding-safe general case).

Ragged shapes are handled by the wrapper: q/w tails are zero-padded (zero
rows/cols contribute nothing to the contraction), scale tails pad with 1.0,
and the [M, N] result is sliced back out. float inputs (f32/bf16) take the
same path — the kernel is then a plain scaled matmul, which is what the
differential harness uses to isolate dtype effects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SCALE_KINDS = ("row", "col", "full")


def _dqmm_kernel(q_ref, s_ref, w_ref, o_ref, acc_scr, *, scale_kind: str):
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)          # [bm, bk]
    s = s_ref[...].astype(jnp.float32)          # [bm,1] | [1,bk] | [bm,bk]
    q = q * s                                   # in-register dequant
    w = w_ref[...].astype(jnp.float32)          # [bk, bn]
    acc_scr[...] += jax.lax.dot_general(
        q, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _finalize():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def _pad_to(x, axis, mult, value=0.0):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def normalize_scale(scale, M: int, K: int):
    """Classify a broadcastable scale into a static (scale_kind, 2-D array).

    Accepts scalars/() (broadcast to a [1, K] col scale), [M, 1], [1, K] and
    [M, K]. Higher-rank scales (e.g. the [N, 1, 1, C] maps the 4-D quantizer
    emits) must be reshaped by the caller to the flattened GEMM layout —
    raising here keeps the mapping explicit rather than guessing."""
    scale = jnp.asarray(scale)
    if scale.ndim == 0 or scale.shape in ((1,), (1, 1)):
        return "col", jnp.broadcast_to(scale.reshape(()), (1, K))
    if scale.ndim == 1:
        if scale.shape[0] == K:
            return "col", scale.reshape(1, K)
        if scale.shape[0] == M:
            return "row", scale.reshape(M, 1)
    if scale.ndim == 2:
        if scale.shape == (M, 1):
            return "row", scale
        if scale.shape == (1, K):
            return "col", scale
        if scale.shape == (M, K):
            return "full", scale
    raise ValueError(
        f"scale shape {scale.shape} not broadcastable to q ({M}, {K}); "
        "reshape higher-rank quantizer scales to the GEMM layout first")


def dequant_matmul_fwd(q: jnp.ndarray, scale, w: jnp.ndarray, *,
                       block_m: int = 256, block_n: int = 256,
                       block_k: int = 256,
                       out_dtype=jnp.float32,
                       interpret: bool = False) -> jnp.ndarray:
    """``(q.astype(f32) * scale) @ w`` without materializing the f32 q.

    q: [M, K] int8 (or f32/bf16); scale broadcastable to q (see
    ``normalize_scale``); w: [K, N] -> [M, N] ``out_dtype`` (f32 default;
    accumulation is always f32)."""
    assert q.ndim == 2 and w.ndim == 2 and q.shape[1] == w.shape[0], \
        (q.shape, w.shape)
    M, K = q.shape
    N = w.shape[1]
    scale_kind, scale = normalize_scale(scale, M, K)

    bm = min(block_m, M)
    bn = min(block_n, N)
    bk = min(block_k, K)
    qp = _pad_to(_pad_to(q, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    Mp, Kp = qp.shape
    Np = wp.shape[1]
    # padded q/w entries are zero, so any finite scale works in the tail;
    # 1.0 keeps the dequant product exactly zero even for denormal tails.
    if scale_kind == "row":
        sp = _pad_to(scale, 0, bm, value=1.0)
        s_spec = pl.BlockSpec((bm, 1), lambda i, j, kx: (i, 0))
    elif scale_kind == "col":
        sp = _pad_to(scale, 1, bk, value=1.0)
        s_spec = pl.BlockSpec((1, bk), lambda i, j, kx: (0, kx))
    else:
        sp = _pad_to(_pad_to(scale, 0, bm, value=1.0), 1, bk, value=1.0)
        s_spec = pl.BlockSpec((bm, bk), lambda i, j, kx: (i, kx))

    grid = (Mp // bm, Np // bn, Kp // bk)
    kernel = functools.partial(_dqmm_kernel, scale_kind=scale_kind)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kx: (i, kx)),
            s_spec,
            pl.BlockSpec((bk, bn), lambda i, j, kx: (kx, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kx: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.dtype(out_dtype)),
        scratch_shapes=[_vmem((bm, bn), jnp.float32)],
        interpret=interpret,
    )(qp, sp, wp)
    return out[:M, :N]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
