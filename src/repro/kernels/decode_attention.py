"""Flash-decode — Pallas TPU kernel for one-token decode against a long KV
cache (the decode_32k / long_500k hot spot).

One query token per sequence attends to S cached keys. Grid:
(batch, q_heads, num_kv_blocks); scratch carries the running (m, l, acc)
log-sum-exp merge across kv blocks — identical math to flash attention with
block_q == 1, but the q row stays resident and kv streams HBM->VMEM at
near-peak bandwidth (this op is purely memory-bound: arithmetic intensity
~1 FLOP/byte).

``length`` masks the valid cache prefix so a preallocated max-seq cache can
be used. GQA via index_map head mapping (no kv repeat in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int):
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]

    @pl.when(kj * block_k < length)
    def _body():
        q = q_ref[0, 0, :].astype(jnp.float32)  # [dk]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, dk]
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # [bk, dv]
        s = jnp.sum(k * q[None, :], axis=1) * scale  # [bk]
        pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[0] = l_scr[0] * corr + jnp.sum(p)
        acc_scr[...] = acc_scr[...] * corr + jnp.sum(p[:, None] * v, axis=0)
        m_scr[0] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0, 0, :] = (acc_scr[...] / jnp.maximum(l_scr[0], 1e-30)
                          ).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     length, *, scale: float = None, block_k: int = 1024,
                     interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hq, d]; k/v: [B, S, Hkv, d]; length: [B] int32 -> [B, Hq, dv].

    Ragged S zero-pads the cache axis up to block alignment — the kernel's
    ``pos < length`` mask and block gate already ignore everything past the
    valid prefix, so padding needs no kernel change. ``length == 0`` rows
    (empty cache) return zeros: the gated body never runs, matching
    ``ref.decode_attention_ref``."""
    B, Hq, dk = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else dk ** -0.5
    block_k = min(block_k, S)
    if S % block_k:
        Sp = ((S + block_k - 1) // block_k) * block_k
        pad = [(0, 0), (0, Sp - S), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        S = Sp
    grid = (B, Hq, S // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dk), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, block_k, 1, dk), lambda b, h, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, dv), lambda b, h, j: (b, j, h // g, 0)),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, dv), q.dtype),
        scratch_shapes=[_vmem((1,), jnp.float32), _vmem((1,), jnp.float32),
                        _vmem((dv,), jnp.float32)],
        interpret=interpret,
    )(q, k, v, length)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
