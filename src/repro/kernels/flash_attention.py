"""Causal flash attention forward — Pallas TPU kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks). TPU grids iterate the
minor-most dim sequentially per core, so VMEM scratch (running max m, denom
l, f32 accumulator) persists across the kv-block loop — the canonical online
softmax. GQA is handled in the k/v index_maps (kv_head = q_head // group), so
kv is never repeated in HBM. Causal skipping is a dynamic pl.when gate: fully
masked kv blocks do no compute.

Backward uses jax.custom_vjp with full recompute through the XLA blockwise
reference (flash-style bwd kernel is a follow-up; recompute keeps memory at
O(S) while staying exact).

Block shapes default to (block_q=512, block_k=512) x head_dim — MXU-aligned
(multiples of 128 in the contracted dim via head_dim, and 512 rows amortize
the VPU softmax ops). VMEM footprint per step:
q (512 x hd) + k,v (512 x hd) + acc (512 x hd f32) + s (512x512 f32) ~ 2.3 MB
at hd=128 — comfortably inside the ~16 MB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, block_q: int, block_k: int,
                      seq_len: int = None):
    # seq_len (static) is set only when the wrapper zero-padded a ragged S:
    # cols >= seq_len are masked and fully-padded kv blocks are skipped like
    # causal skipping (an all-masked block would corrupt the online softmax:
    # m stays NEG_INF and exp(s - m) = 1 inflates the denominator).
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # [bq, dk]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, dk]
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # [bk, dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        if seq_len is not None:
            cols = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols < seq_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    run = None
    if causal:
        # dynamic structured skip: kv block strictly after the q block's end
        run = kj * block_k <= qi * block_q + block_q - 1
    if seq_len is not None:
        pad_skip = kj * block_k < seq_len
        run = pad_skip if run is None else (run & pad_skip)
    if run is None:
        _body()
    else:
        pl.when(run)(_body)

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, scale: float = None,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False) -> jnp.ndarray:
    """q: [B, S, Hq, d]; k/v: [B, S, Hkv, d]; Hq % Hkv == 0 -> [B, S, Hq, d].

    Ragged S (not a multiple of the block shapes) is handled by zero-padding
    the sequence axis up to ``lcm(block_q, block_k)`` alignment and masking
    the padded key columns inside the kernel; padded query rows are sliced
    off the output. A divisible S takes the exact pre-padding graph."""
    import math

    B, S, Hq, dk = q.shape
    Hkv = k.shape[2]
    dv = v.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else dk ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    seq_len = None
    if S % block_q or S % block_k:
        align = math.lcm(block_q, block_k)
        Sp = ((S + align - 1) // align) * align
        pad = [(0, 0), (0, Sp - S), (0, 0), (0, 0)]
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        seq_len, S = S, Sp
    grid = (B, Hq, S // block_q, S // block_k)

    kernel = functools.partial(_flash_fwd_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k,
                               seq_len=seq_len)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dk), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, dk), lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, dv), lambda b, h, i, j: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dv), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, Hq, dv), q.dtype),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :seq_len] if seq_len is not None else out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
