"""Fused block-perturbation reduction — Pallas TPU kernel.

The pace controller (paper Eq. 2) needs, every round, for the active block:
  * ||theta^r - theta^{r-1}||^2   (update norm, denominator FIFO)
  * ||theta^r - theta^{r-Q}||^2   (telescoped window numerator)

This kernel fuses (subtract -> square -> reduce) over a flat parameter
buffer in one HBM pass instead of materializing the diff (2 reads + 0 writes
per element vs 3 reads + 1 write unfused). Grid: 1-D over row blocks; a
scalar VMEM accumulator persists across the sequential grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536  # 64k elements per step: 512 KiB of f32 per operand in VMEM


def _diff_sq_kernel(a_ref, b_ref, o_ref, acc_scr):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    d = a_ref[...].astype(jnp.float32) - b_ref[...].astype(jnp.float32)
    acc_scr[0] += jnp.sum(d * d)

    @pl.when(i == n - 1)
    def _fin():
        o_ref[0] = acc_scr[0]


def diff_sqnorm(a: jnp.ndarray, b: jnp.ndarray, *, block: int = BLOCK,
                interpret: bool = False) -> jnp.ndarray:
    """sum((a - b)^2) over flat equal-shape arrays (any dtype) -> f32 scalar."""
    a = a.reshape(-1)
    b = b.reshape(-1)
    n = a.shape[0]
    block = min(block, n)
    pad = (-n) % block
    if pad:
        a = jnp.pad(a, (0, pad))
        b = jnp.pad(b, (0, pad))
    grid = ((n + pad) // block,)
    return pl.pallas_call(
        _diff_sq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        scratch_shapes=[_vmem((1,), jnp.float32)],
        interpret=interpret,
    )(a, b)[0]


def tree_diff_sqnorm(tree_a, tree_b, *, interpret: bool = False) -> jnp.ndarray:
    """sum over leaves of ||a - b||^2 (the pace controller's on-mesh half)."""
    parts = [diff_sqnorm(x, y, interpret=interpret)
             for x, y in zip(jax.tree.leaves(tree_a), jax.tree.leaves(tree_b))]
    return jnp.sum(jnp.stack(parts))


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
