"""Sparse cohort scatter-add — Pallas TPU kernel for the compressed-uplink
Eq. 1 fold (fl/engine.py / fl/compression.py).

After top-k sparsification, each of K clients uploads (idx [k], vals [k])
per leaf. The XLA path densifies via one ``.at[].add`` scatter over the
[K*k] concatenation; this kernel folds the whole cohort in ONE launch:

Grid: (K,) — TPU grids iterate sequentially per core, so the full dense
[L] output block (constant index_map) stays VMEM-resident across client
steps: zeroed at step 0, then each step streams one client's (idx, vals)
row from HBM and read-modify-writes ``w_i * vals`` into it with dynamic
``pl.ds`` single-element stores. Sequential grid execution makes duplicate
indices — within a row or across clients — accumulate exactly like the
reference scatter-add (no atomics needed).

The dense block must fit VMEM, so the public wrapper (kernels/ops.py)
falls back to the XLA scatter for leaves above ``MAX_VMEM_ELEMS`` — the
documented dispatch rule (docs/ARCHITECTURE.md). FL leaves are per-stage
tensors well under that bound in every config this repo ships.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# f32 elements per leaf the dense output block may occupy in VMEM (8 MiB of
# the ~16 MiB budget, leaving room for the (idx, vals) row stream).
MAX_VMEM_ELEMS = 1 << 21


def _sparse_agg_kernel(idx_ref, val_ref, w_ref, o_ref, *, k: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[0]

    def body(j, _):
        at = idx_ref[0, j]
        cur = pl.load(o_ref, (pl.ds(at, 1),))
        pl.store(o_ref, (pl.ds(at, 1),),
                 cur + w * val_ref[0, j].astype(jnp.float32))
        return _

    jax.lax.fori_loop(0, k, body, 0)


def sparse_cohort_add_fwd(idx: jnp.ndarray, vals: jnp.ndarray,
                          weights: jnp.ndarray, length: int, *,
                          interpret: bool = False) -> jnp.ndarray:
    """Dense [length] f32 Eq. 1 fold of K sparse client rows.

    idx: [K, k] int32 flat indices (duplicates allowed — they accumulate);
    vals: [K, k]; weights: [K]. Exactly matches
    ``fl.compression.ingraph_sparse_aggregate``."""
    K, k = idx.shape
    assert vals.shape == (K, k) and weights.shape == (K,), \
        (idx.shape, vals.shape, weights.shape)
    kernel = functools.partial(_sparse_agg_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((length,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((length,), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), vals, weights.astype(jnp.float32))
