"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """q: [B,S,Hq,d]; k/v: [B,S,Hkv,d]."""
    B, S, Hq, d = q.shape
    g = Hq // k.shape[2]
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, length, *, scale=None):
    """q: [B,Hq,d]; k/v: [B,S,Hkv,d]; length: [B]. Rows with length == 0
    return zeros (nothing to attend to) — matching the Pallas kernel, whose
    masked body never runs for an empty cache."""
    B, Hq, d = q.shape
    S = k.shape[1]
    g = Hq // k.shape[2]
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, None, :] < length[:, None, None]
    s = jnp.where(valid, s, -1e30)
    p = jnp.where(length[:, None, None] > 0, jax.nn.softmax(s, axis=-1), 0.0)
    return jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, log_a, Bm, Cm):
    """Sequential SSD recurrence (exact). x: [B,S,H,hd]; dt/log_a: [B,S,H];
    Bm/Cm: [B,S,N] -> y: [B,S,H,hd]."""
    B, S, H, hd = x.shape
    N = Bm.shape[-1]

    def step(h, inputs):
        x_t, dt_t, la_t, b_t, c_t = inputs
        a = jnp.exp(la_t)  # [B,H]
        h = a[..., None, None] * h + jnp.einsum(
            "bh,bhd,bN->bhdN", dt_t, x_t.astype(jnp.float32), b_t.astype(jnp.float32))
        y = jnp.einsum("bN,bhdN->bhd", c_t.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, H, hd, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (x.transpose(1, 0, 2, 3),
                                    dt.transpose(1, 0, 2).astype(jnp.float32),
                                    log_a.transpose(1, 0, 2).astype(jnp.float32),
                                    Bm.transpose(1, 0, 2),
                                    Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def diff_sqnorm_ref(a, b):
    d = a.astype(jnp.float32) - b.astype(jnp.float32)
    return jnp.sum(d * d)


def dequant_matmul_ref(q, scale, w, *, out_dtype=jnp.float32):
    """(q.astype(f32) * scale) @ w — the XLA broadcast-dequant GEMM that
    kernels/dequant_matmul.py fuses. f32 accumulation on every input dtype."""
    x = q.astype(jnp.float32) * jnp.asarray(scale).astype(jnp.float32)
    return jax.lax.dot_general(
        x, w.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_dtype)


def sparse_cohort_add_ref(idx, vals, weights, length):
    """Dense [length] f32 fold of K sparse client rows — the scatter-add in
    fl.compression.ingraph_sparse_aggregate, restated here so kernels/ has
    an import-independent oracle."""
    contrib = (weights.astype(jnp.float32)[:, None]
               * vals.astype(jnp.float32)).reshape(-1)
    return jnp.zeros(length, jnp.float32).at[
        idx.reshape(-1).astype(jnp.int32)].add(contrib)
