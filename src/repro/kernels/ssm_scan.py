"""Chunked SSD (Mamba2) selective-state scan — Pallas TPU kernel.

Grid: (batch, heads, num_chunks); the SSM state h [head_dim, N] lives in VMEM
scratch and persists across the chunk loop (TPU sequential minor-most grid),
so the recurrence never round-trips HBM. Per chunk:

  intra:  y_i += C_i . (sum_{j<=i} L_ij dt_j x_j B_j)   (quadratic in chunk)
  inter:  y_i += C_i . (prod_{l<=i} a_l) h_enter
  state:  h <- (prod a) h + sum_j (prod_{l>j} a_l) dt_j x_j B_j^T

Chunk = 128 rows (MXU-aligned); VMEM per step: x (128 x hd) + B,C (128 x N)
+ state (hd x N f32) + L (128 x 128 f32) — well under budget at hd=128, N=64.
All decay math in fp32 log space (stable segsum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, la_ref, b_ref, c_ref, y_ref, h_scr, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # [c, hd]
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # [c]
    la = la_ref[0, :, 0].astype(jnp.float32)       # [c] log decay
    Bm = b_ref[0, :, :].astype(jnp.float32)        # [c, N]
    Cm = c_ref[0, :, :].astype(jnp.float32)        # [c, N]

    # segsum decay matrix L[i, j] = exp(sum_{l=j+1..i} la_l), lower-tri
    cum = jnp.cumsum(la)
    diff = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(rows >= cols, jnp.exp(diff), 0.0)

    # intra-chunk: scores = (C B^T * L * dt_j); y_intra = scores @ x
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [c, c]
    scores = cb * L * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: decay-to-position-i applied to entering state
    head = jnp.exp(cum)  # prod_{l<=i} a_l
    y += head[:, None] * jax.lax.dot_general(
        Cm, h_scr[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h <- (prod a) h + sum_j w_j x_j B_j^T ; w_j = dt_j prod_{l>j} a_l
    total = cum[chunk - 1]
    w = jnp.exp(total - cum) * dt  # [c]
    outer = jax.lax.dot_general(x * w[:, None], Bm, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [hd, N]
    h_scr[...] = jnp.exp(total) * h_scr[...] + outer

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, log_a: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = False) -> jnp.ndarray:
    """x: [B,S,H,hd]; dt/log_a: [B,S,H]; Bm/Cm: [B,S,N] -> y: [B,S,H,hd]."""
    B, S, H, hd = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    grid = (B, H, S // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), x.dtype),
        scratch_shapes=[_vmem((hd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, log_a, Bm, Cm)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
