from repro.optim.optimizers import (Optimizer, sgd, momentum, adamw,
                                    apply_updates, global_norm, clip_by_global_norm)
from repro.optim.schedules import constant, cosine, warmup_cosine
