"""Learning-rate schedules (step -> lr, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32) / total_steps, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * (final_frac + (1 - final_frac) * cos)
    return f


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * jnp.where(s < warmup_steps, warm, cos)
    return f
