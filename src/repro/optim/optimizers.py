"""Optimizers as pure pytree transformations (no optax available offline).

Each ``Optimizer`` is (init, update): ``update(grads, state, params)`` returns
(updates, new_state) where updates are ADDED to params. AdamW keeps fp32
m/v (and applies updates in fp32 before casting back), so bf16 params train
stably. Frozen blocks simply never enter the optimizer — that absence IS the
paper's M_optimizer saving; no masking machinery is needed because SmartFreeze
splits the param tree itself (core/freezing.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def _lr(schedule: Schedule, step):
    return schedule(step) if callable(schedule) else schedule


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr(lr, step)
        ups = jax.tree.map(lambda g: (-lr_t * g.astype(jnp.float32)), grads)
        return ups, {"step": step}

    return Optimizer(init, update)


def momentum(lr: Schedule, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32),
                          state["mu"], grads)
        lr_t = _lr(lr, step)
        ups = jax.tree.map(lambda m: -lr_t * m, mu)
        return ups, {"step": step, "mu": mu}

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = _lr(lr, step)

        def upd(m_, v_, p):
            u = -lr_t * ((m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u

        ups = jax.tree.map(upd, m, v, params)
        return ups, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
