"""Render the roofline table from experiments/dryrun/*.json (markdown)."""
from __future__ import annotations

import glob
import json
import os
import sys


def fmt_s(x):
    if x is None:
        return "-"
    if x < 1e-3:
        return f"{x * 1e6:.0f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def load(out_dir="experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def table(rows, mesh="single"):
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "useful-FLOPs | roofline-frac | peak GiB/chip |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | skip | | | | | | "
                       f"{r['skipped'][:46]} |")
            continue
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | |")
            continue
        peak = (r.get("memory_analysis") or {}).get("peak_bytes")
        peak_s = f"{peak / 2**30:.1f}" if peak else "-"
        uf = r.get("useful_flops_ratio")
        rf = r.get("roofline_fraction")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant']} | {uf:.2f} | {rf:.2f} | {peak_s} |"
            if uf is not None and rf is not None else
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant']} | - | - | {peak_s} |")
    return "\n".join(out)


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rows = load(out_dir)
    for mesh in ("single", "multi"):
        n = sum(1 for r in rows if r["mesh"] == mesh)
        print(f"\n### {mesh}-pod mesh ({n} cells)\n")
        print(table(rows, mesh))


if __name__ == "__main__":
    main()
