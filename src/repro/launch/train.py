"""End-to-end progressive federated training driver.

Runs SmartFreeze on any ``--arch``: per stage, build the (frozen, active)
split + output module, run federated rounds (pods = cross-silo clients; on
CPU this is a 1-pod debug mesh), feed the pace controller with the aggregated
active block each round, freeze on convergence, grow, repeat.

Round orchestration goes through ``fl/sim.py``'s ``FederatedLoop`` — the
same virtual-time loop the CNN servers and baselines drive — with pods as
the "clients". Checkpoints (atomic/async) every ``--ckpt-every`` rounds now
carry the pace-controller window and the data RNG stream alongside the
merged params, so ``--resume`` continues the perturbation series and data
order mid-stage instead of restarting the stage.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 40 --batch 8 --seq 128
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 8 --batch 4 --seq 64 --pods 8 --mesh-clients 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core import freezing
from repro.core.pace import PaceController
from repro.data.synthetic import make_lm_batch
from repro.fl.sim import (FederatedLoop, pack_rng_state, tree_like,
                          unpack_rng_state)
from repro.models.transformer import build
from repro.optim import adamw, sgd, warmup_cosine


def train(arch: str, *, reduced: bool = True, steps: int = 40, batch: int = 8,
          seq: int = 128, local_steps: int = 1, num_pods: int = 1,
          lr: float = 3e-3, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 20, resume: bool = False, remat: bool = False,
          d_model: int = 0, num_layers: int = 0, log_every: int = 5,
          pace_kwargs: Optional[dict] = None, seed: int = 0,
          compute_dtype: Optional[str] = None,
          mesh_clients: int = 0, use_pallas: bool = False) -> dict:
    cfg = configs.get(arch)
    mesh = None
    if mesh_clients and mesh_clients > 1:
        # client-axis mesh: the pod dimension (the LM loop's cross-silo
        # "clients") partitions across devices; make_fed_round_step's
        # vmap-over-pods then runs SPMD under GSPMD with replicated params.
        # Pods that don't divide the axis fall back to single-device
        # placement (the make_rules divisibility discipline).
        from repro.dist.sharding import client_axis_size
        from repro.launch.mesh import make_client_mesh
        mesh = make_client_mesh(mesh_clients)
        if client_axis_size(mesh) < mesh_clients:
            # the easy mistake: XLA_FLAGS forcing host devices was not set
            # before jax initialized, so fewer devices are visible than
            # requested — say so instead of silently running smaller
            print(f"--mesh-clients: requested {mesh_clients} devices but "
                  f"only {client_axis_size(mesh)} visible (set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=N before jax "
                  "initializes?)")
        if num_pods % client_axis_size(mesh) != 0:
            print(f"--mesh-clients: {num_pods} pods do not divide the "
                  f"{client_axis_size(mesh)}-device client axis; running "
                  "replicated")
            mesh = None
    if reduced:
        over = {}
        if d_model:
            over["d_model"] = d_model
        if num_layers:
            over["num_layers"] = num_layers
        cfg = cfg.reduced(**over)
    if compute_dtype:
        # mixed-precision tier knob: bf16 forward/backward per pod while the
        # Eq. 1 aggregation and checkpoint stream keep the param dtype
        cfg = dataclasses.replace(cfg, compute_dtype=compute_dtype)
    if use_pallas:
        # route GQA full-sequence attention through the Pallas flash kernel
        # (kernels/flash_attention.py; interpret mode off-TPU). Roofline
        # selection rationale: launch/roofline.py ranks attention as the
        # top compute-bound hot path at LM scale. XLA stays the default.
        if cfg.attention != "gqa":
            raise SystemExit("--use-pallas: only the GQA attention flavour "
                             f"has a Pallas kernel (arch uses "
                             f"{cfg.attention!r})")
        cfg = dataclasses.replace(cfg, attention_impl="pallas")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    T = cfg.num_freeze_blocks
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    rng = np.random.RandomState(seed)
    start_stage, start_in_stage = 0, 0
    restored_pace = None
    restored_active = None
    restored_global = None
    if resume and mgr is not None:
        try:
            ck = mgr.restore()
            meta = ck["metadata"]
            tree = ck["tree"]
            saved = tree.get("params", tree)  # legacy ckpts stored bare params
            params = jax.tree.map(lambda a, b: jnp.asarray(b, a.dtype), params,
                                  saved)
            if "rng" in tree:
                rng = unpack_rng_state(tree["rng"])
            restored_pace = tree.get("pace")
            restored_active = tree.get("active")  # incl. the op module
            restored_global = meta.get("global_round")
            start_stage, start_in_stage = meta["stage"], meta["round"] + 1
            if meta.get("frozen"):
                # checkpoint landed on a pace-freeze round: params already
                # carry that stage's merge — continue with the next stage
                start_stage, start_in_stage = start_stage + 1, 0
                restored_pace = restored_active = None
            print(f"resumed from stage {start_stage} round {start_in_stage}")
        except FileNotFoundError:
            pass

    history = []
    rounds_per_stage = max(steps // T, 1)
    if start_in_stage >= rounds_per_stage:
        # checkpoint landed on a stage's final round: params already carry
        # the finished stage's merge — continue with the next stage
        start_stage, start_in_stage = start_stage + 1, 0
    # prefer the checkpointed global index: stages frozen early ran fewer
    # than rounds_per_stage rounds, so recomputing from stage*rps drifts
    global_round = (restored_global + 1 if restored_global is not None
                    else start_stage * rounds_per_stage + start_in_stage)

    for stage in range(start_stage, T):
        plan = freezing.make_stage_plan(cfg, stage)
        frozen, active = freezing.init_stage_active(
            model, params, plan, jax.random.PRNGKey(seed + 100 + stage))
        opt = sgd(lr)
        step_fn = jax.jit(freezing.make_fed_round_step(
            model, plan, opt, num_pods=num_pods, local_steps=local_steps,
            remat=remat))
        pace = PaceController(**(pace_kwargs or dict(
            min_rounds=max(rounds_per_stage // 2, 3), mu=2,
            slope_lambda=5e-3)))
        r0 = start_in_stage if stage == start_stage else 0
        if r0 and restored_pace is not None:
            pace.load_state_dict(restored_pace)
            restored_pace = None
        if r0 and restored_active is not None:
            # merged params don't carry the op module — restore the full
            # active tree so mid-stage resume keeps its trained state
            active = tree_like(active, restored_active)
            restored_active = None
        t_stage = time.time()
        box = {"active": active, "stage_round": r0}

        def train_fn(cohort, r, sequential=None, _box=box, _step=step_fn,
                     _frozen=frozen):
            data = make_lm_batch(cfg, num_pods * local_steps * batch, seq,
                                 seed=rng.randint(1 << 30))
            fed = {k: jnp.asarray(v).reshape(
                (num_pods, local_steps, batch) + v.shape[1:])
                for k, v in data.items()}
            if mesh is not None:
                from repro.dist.sharding import shard_cohort
                fed = shard_cohort(mesh, fed)
            w = jnp.ones((num_pods,), jnp.float32)
            _box["active"], metrics = _step(_box["active"], _frozen, fed, w)
            loss = float(metrics["loss"])
            return {pod: loss for pod in cohort}

        def on_round(rec, _box=box, _pace=pace, _stage=stage):
            r = _box["stage_round"]
            loss = next(iter(rec.losses.values())) if rec.losses else float("nan")
            p = _pace.observe(_box["active"]["runs"])
            history.append({"stage": _stage, "round": r, "loss": loss,
                            "perturbation": p})
            if r % log_every == 0:
                print(f"stage {_stage} round {r:3d} loss {loss:.4f} "
                      f"P={p if p is None else round(p, 4)}")
            freeze = _pace.should_freeze()
            if mgr and (rec.round_idx + 1) % ckpt_every == 0:
                merged = freezing.merge_stage_params(model, params, plan,
                                                     _box["active"])
                mgr.save(rec.round_idx,
                         {"params": merged, "active": _box["active"],
                          "pace": _pace.state_dict(),
                          "rng": pack_rng_state(rng)},
                         metadata={"stage": _stage, "round": r,
                                   "global_round": rec.round_idx,
                                   "frozen": bool(freeze),
                                   "compute_dtype": cfg.compute_dtype})
            _box["stage_round"] = r + 1
            if freeze:
                print(f"stage {_stage} frozen by pace controller at round {r}")
            return freeze

        loop = FederatedLoop(select_fn=lambda r, avail: avail,
                             train_fn=train_fn,
                             client_ids=list(range(num_pods)),
                             on_round=on_round)
        done = loop.run(rounds_per_stage - r0, start_round=global_round)
        global_round += len(done)
        params = freezing.merge_stage_params(model, params, plan, box["active"])
        print(f"stage {stage} done in {time.time() - t_stage:.0f}s")

    if mgr:
        mgr.save(global_round, {"params": params,
                                "rng": pack_rng_state(rng)},
                 metadata={"stage": T - 1, "round": rounds_per_stage,
                           "global_round": global_round,
                           "compute_dtype": cfg.compute_dtype})
        mgr.wait()
    return {"params": params, "history": history, "config": cfg}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--num-layers", type=int, default=0)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--compute-dtype", default=None,
                    help="override the arch's compute dtype "
                         "(e.g. bfloat16 / float32)")
    ap.add_argument("--mesh-clients", type=int, default=0,
                    help="shard the pod (client) axis over this many "
                         "devices (launch.mesh.make_client_mesh); 0 = "
                         "single-device. On CPU, force host devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    ap.add_argument("--use-pallas", action="store_true",
                    help="run GQA attention through the Pallas flash "
                         "kernel (kernels/); default keeps the XLA path")
    a = ap.parse_args()
    out = train(a.arch, reduced=a.reduced, steps=a.steps, batch=a.batch,
                seq=a.seq, local_steps=a.local_steps, num_pods=a.pods,
                lr=a.lr, ckpt_dir=a.ckpt_dir, resume=a.resume, remat=a.remat,
                d_model=a.d_model, num_layers=a.num_layers,
                compute_dtype=a.compute_dtype, mesh_clients=a.mesh_clients,
                use_pallas=a.use_pallas)
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"finished: {len(losses)} rounds, "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    else:
        print("finished: nothing left to run (checkpoint already complete)")


if __name__ == "__main__":
    main()
