"""End-to-end progressive federated training driver.

Runs SmartFreeze on any ``--arch``: per stage, build the (frozen, active)
split + output module, run federated rounds (pods = cross-silo clients; on
CPU this is a 1-pod debug mesh), feed the pace controller with the aggregated
active block each round, freeze on convergence, grow, repeat. Checkpoints
(atomic/async) every ``--ckpt-every`` rounds; ``--resume`` restores params +
stage + round.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 40 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core import freezing
from repro.core.pace import PaceController
from repro.data.synthetic import make_lm_batch
from repro.models.transformer import build
from repro.optim import adamw, sgd, warmup_cosine


def train(arch: str, *, reduced: bool = True, steps: int = 40, batch: int = 8,
          seq: int = 128, local_steps: int = 1, num_pods: int = 1,
          lr: float = 3e-3, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 20, resume: bool = False, remat: bool = False,
          d_model: int = 0, num_layers: int = 0, log_every: int = 5,
          pace_kwargs: Optional[dict] = None, seed: int = 0) -> dict:
    cfg = configs.get(arch)
    if reduced:
        over = {}
        if d_model:
            over["d_model"] = d_model
        if num_layers:
            over["num_layers"] = num_layers
        cfg = cfg.reduced(**over)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    T = cfg.num_freeze_blocks
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    start_stage, start_round = 0, 0
    if resume and mgr is not None:
        try:
            ck = mgr.restore()
            meta = ck["metadata"]
            params = jax.tree.map(lambda a, b: jnp.asarray(b, a.dtype), params,
                                  ck["tree"])
            start_stage, start_round = meta["stage"], meta["round"] + 1
            print(f"resumed from stage {start_stage} round {start_round}")
        except FileNotFoundError:
            pass

    history = []
    rounds_per_stage = max(steps // T, 1)
    rng = np.random.RandomState(seed)
    global_round = 0

    for stage in range(start_stage, T):
        plan = freezing.make_stage_plan(cfg, stage)
        frozen, active = freezing.init_stage_active(
            model, params, plan, jax.random.PRNGKey(seed + 100 + stage))
        opt = sgd(lr)
        step_fn = jax.jit(freezing.make_fed_round_step(
            model, plan, opt, num_pods=num_pods, local_steps=local_steps,
            remat=remat))
        pace = PaceController(**(pace_kwargs or dict(
            min_rounds=max(rounds_per_stage // 2, 3), mu=2,
            slope_lambda=5e-3)))
        t_stage = time.time()
        for r in range(rounds_per_stage):
            data = make_lm_batch(cfg, num_pods * local_steps * batch, seq,
                                 seed=rng.randint(1 << 30))
            fed = {k: jnp.asarray(v).reshape(
                (num_pods, local_steps, batch) + v.shape[1:])
                for k, v in data.items()}
            w = jnp.ones((num_pods,), jnp.float32)
            active, metrics = step_fn(active, frozen, fed, w)
            p = pace.observe(active["runs"])
            history.append({"stage": stage, "round": r,
                            "loss": float(metrics["loss"]),
                            "perturbation": p})
            if r % log_every == 0:
                print(f"stage {stage} round {r:3d} loss {metrics['loss']:.4f} "
                      f"P={p if p is None else round(p, 4)}")
            if mgr and (global_round + 1) % ckpt_every == 0:
                merged = freezing.merge_stage_params(model, params, plan, active)
                mgr.save(global_round, merged,
                         metadata={"stage": stage, "round": r})
            global_round += 1
            if pace.should_freeze():
                print(f"stage {stage} frozen by pace controller at round {r}")
                break
        params = freezing.merge_stage_params(model, params, plan, active)
        print(f"stage {stage} done in {time.time() - t_stage:.0f}s")

    if mgr:
        mgr.save(global_round, params, metadata={"stage": T - 1,
                                                 "round": global_round})
        mgr.wait()
    return {"params": params, "history": history, "config": cfg}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--num-layers", type=int, default=0)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--remat", action="store_true")
    a = ap.parse_args()
    out = train(a.arch, reduced=a.reduced, steps=a.steps, batch=a.batch,
                seq=a.seq, local_steps=a.local_steps, num_pods=a.pods,
                lr=a.lr, ckpt_dir=a.ckpt_dir, resume=a.resume, remat=a.remat,
                d_model=a.d_model, num_layers=a.num_layers)
    losses = [h["loss"] for h in out["history"]]
    print(f"finished: {len(losses)} rounds, loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
