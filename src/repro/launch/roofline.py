"""Three-term roofline extraction from a compiled dry-run artifact.

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / ICI_bw

IMPORTANT METHODOLOGY NOTE: ``compiled.cost_analysis()`` counts while-loop
bodies ONCE — a scan-over-layers train step under-reports FLOPs by ~L×
(verified empirically; see tests/test_roofline_parser.py). We therefore parse
the optimized HLO text ourselves and weight every instruction by the product
of its enclosing while-loops' trip counts:

  * FLOPs: every ``dot`` op contributes 2 * prod(result dims) * prod(lhs
    contracting dim sizes). (Elementwise FLOPs are ignored — dots dominate
    the compute term; softmax/norm traffic shows up in the memory term.)
  * bytes: fusions contribute their parameter reads + result write; other
    ops contribute 2x result bytes (read+write amortized) — an HBM-traffic
    estimate assuming each materialized buffer is written once and read once.
  * collectives: result-shape bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

Raw cost_analysis numbers are kept alongside for reference.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.launch import mesh as mesh_mod

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_dims(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.match(shape_str)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ---------------------------------------------------------------------------
# HLO structural parsing
# ---------------------------------------------------------------------------


def _parse_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        # header: [ENTRY] %name (params...) -> result { — params may nest parens
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{$", s)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if s == "}" or s == "})":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def _while_trip_count(cond_lines: List[str]) -> Optional[int]:
    consts = []
    for line in cond_lines:
        m = re.search(r"s32\[\]\s+constant\((\d+)\)", line)
        if m:
            consts.append(int(m.group(1)))
    return max(consts) if consts else None


def _called_computations(line: str) -> List[str]:
    names = []
    for key in ("body=", "condition=", "to_apply=", "calls="):
        for m in re.finditer(key + r"%?([\w\.\-]+)", line):
            names.append(m.group(1))
    return names


def _multipliers(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """computation -> product of enclosing while trip counts."""
    called = set()
    for lines in comps.values():
        for line in lines:
            called.update(_called_computations(line))
    roots = [n for n in comps if n not in called]
    mult: Dict[str, int] = {}
    stack = [(r, 1) for r in roots]
    seen = set()
    while stack:
        name, m = stack.pop()
        if (name, m) in seen:
            continue
        seen.add((name, m))
        mult[name] = max(mult.get(name, 0), m)
        for line in comps.get(name, []):
            trip = 1
            if re.search(r"\bwhile\(", line):
                mm = re.search(r"condition=%?([\w\.\-]+)", line)
                tc = _while_trip_count(comps.get(mm.group(1), [])) if mm else None
                trip = tc if tc else 1
            for c in _called_computations(line):
                stack.append((c, m * trip))
    return mult


_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_DOT_LHS_RE = re.compile(
    r"\bdot\(\s*(?:([a-z0-9]+\[[0-9,]*\])(?:\{[^}]*\})?\s+)?%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _symbol_table(lines: List[str]) -> Dict[str, str]:
    """instruction name -> result shape string (within one computation)."""
    table: Dict[str, str] = {}
    for line in lines:
        m = _INSTR_RE.match(line)
        if m:
            table[m.group(1)] = m.group(2)
    return table


def _dot_flops(line: str, table: Dict[str, str]) -> int:
    """dot FLOPs = 2 * prod(result dims) * prod(lhs contracting dim sizes).

    HLO operands are bare names — lhs shape is resolved via the computation's
    symbol table."""
    m = _INSTR_RE.match(line)
    if not m or m.group(3) != "dot":
        return 0
    out = _shape_dims(m.group(2))
    om = _DOT_LHS_RE.search(line)
    if out is None or om is None:
        return 0
    # newer XLA prints operand shapes inline: dot(f32[128,256]{1,0} %a, ...)
    if om.group(1):
        lhs = _shape_dims(om.group(1))
    else:  # older format: bare operand name, resolve via table
        lhs_shape = table.get(om.group(2))
        lhs = _shape_dims(lhs_shape) if lhs_shape else None
    cm = _LHS_CONTRACT_RE.search(line)
    contract = 1
    if lhs is not None and cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs[1]):
                contract *= lhs[1][i]
    n_out = 1
    for d in out[1]:
        n_out *= d
    return 2 * n_out * contract


def _fusion_param_bytes(lines: List[str]) -> int:
    total = 0
    for line in lines:
        if re.search(r"=\s*\S+\s+parameter\(", line):
            m = re.search(r"=\s*(\([^)]*\)|\S+)\s+parameter\(", line)
            if m:
                total += _shape_bytes(m.group(1))
    return total


def hlo_weighted_costs(hlo: str) -> Dict[str, float]:
    """Trip-count-weighted (flops, traffic bytes, collective bytes)."""
    comps = _parse_computations(hlo)
    mult = _multipliers(comps)
    # fusion computations: counted via their call sites
    fusion_comps = set()
    for lines in comps.values():
        for line in lines:
            if re.search(r"\bfusion\(", line):
                for c in _called_computations(line):
                    fusion_comps.add(c)

    flops = 0.0
    traffic = 0.0
    coll_total = 0.0
    coll_by_op = {op: 0.0 for op in COLLECTIVE_OPS}
    # aliasing / buffer-plumbing ops: no HBM traffic of their own
    plumbing = ("parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "bitcast-convert", "copy-start", "copy-done",
                "reshape", "after-all", "iota", "while", "conditional",
                "call", "custom-call", "partition-id", "replica-id")
    for name, lines in comps.items():
        m = mult.get(name, 1)
        in_fusion = name in fusion_comps
        table = _symbol_table(lines)
        for line in lines:
            f = _dot_flops(line, table)
            if f:
                flops += f * m
            if in_fusion:
                continue  # traffic counted at the fusion call site
            im = _INSTR_RE.match(line)
            if im and im.group(3) in plumbing:
                continue
            # result shape = first token after '='
            if "=" not in line:
                continue
            rhs = line.split("=", 1)[1].strip()
            shape_str = rhs.split(" ", 1)[0]
            rbytes = _shape_bytes(shape_str)
            is_coll = False
            for op in COLLECTIVE_OPS:
                if re.search(rf"\b{op}(-start)?\(", rhs):
                    coll_total += rbytes * m
                    coll_by_op[op] += rbytes * m
                    is_coll = True
                    break
            fm = re.search(r"\bfusion\(.*calls=%?([\w\.\-]+)", rhs)
            if fm:
                traffic += (rbytes + _fusion_param_bytes(
                    comps.get(fm.group(1), []))) * m
            elif not is_coll:
                traffic += 2 * rbytes * m
    return {"flops": flops, "bytes": traffic, "collective_bytes": coll_total,
            "collective_by_op": coll_by_op}


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to a flat dict: newer jaxlibs
    return the dict directly, older ones a one-element list of dicts."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def collective_bytes(hlo: str) -> Tuple[int, Dict[str, int]]:
    out = hlo_weighted_costs(hlo)
    return int(out["collective_bytes"]), {k: int(v) for k, v in
                                          out["collective_by_op"].items()}


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


def analyze_compiled(compiled, mesh, cfg, shape) -> Dict:
    from repro.core.memory_model import model_flops_6nd

    n_chips = mesh.size
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    w = hlo_weighted_costs(hlo)

    compute_s = w["flops"] / mesh_mod.PEAK_FLOPS_BF16
    memory_s = w["bytes"] / mesh_mod.HBM_BW
    collective_s = w["collective_bytes"] / mesh_mod.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops_6nd(cfg, shape.global_batch,
                         shape.seq_len if shape.kind == "train" else
                         (shape.seq_len if shape.kind == "prefill" else 1))
    if shape.kind != "train":
        mf /= 3.0  # forward only

    mem_an = {}
    try:
        ma = compiled.memory_analysis()
        mem_an = {"output_bytes": getattr(ma, "output_size_in_bytes", None),
                  "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                  "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                  "peak_bytes": (getattr(ma, "temp_size_in_bytes", 0) or 0)
                  + (getattr(ma, "argument_size_in_bytes", 0) or 0)}
    except Exception:  # noqa: BLE001
        pass

    bound_s = max(terms.values())
    return {
        "n_chips": n_chips,
        "per_chip_flops": w["flops"],
        "per_chip_bytes": w["bytes"],
        "collective_bytes": w["collective_bytes"],
        "collective_by_op": {k: int(v) for k, v in w["collective_by_op"].items()},
        "raw_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "raw_cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant.replace("_s", ""),
        "model_flops_6nd": mf,
        "useful_flops_ratio": (mf / n_chips) / w["flops"] if w["flops"] else None,
        "roofline_fraction": compute_s / bound_s if bound_s else None,
        "tokens": shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1),
        "memory_analysis": mem_an,
    }
