"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips — the "pod" axis is the
federated cross-silo axis (DESIGN.md §2).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1):
    """Tiny mesh over whatever devices exist (tests/smoke)."""
    n = min(n_devices, len(jax.devices()))
    return jax.make_mesh((1, n), ("data", "model"))


def make_client_mesh(n_devices: int | None = None):
    """1-D mesh over the federated cohort axis (``"clients"``).

    The fused round engine (``fl/engine.py``) shard_maps the per-client
    local training over this axis: clients partition across devices, params
    replicate, and the Eq. 1 aggregation is one cross-device ``psum``.
    Defaults to every visible device. CPU testing forces extra host devices
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set BEFORE
    jax import — see tests/test_shard.py and ``benchmarks/run.py
    shard_scale``)."""
    avail = len(jax.devices())
    n = avail if n_devices is None else min(n_devices, avail)
    return jax.make_mesh((max(n, 1),), ("clients",))


# TPU v5e hardware constants (per chip) — §Roofline denominators
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
