"""Batched decode serving driver: prefill-free demo loop over a KV cache.

Serves batched token streams from a small model: greedy decode with the
functional cache (decode_32k-style step). On TPU the same serve_step is what
the dry-run lowers at (arch x decode shape x mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.transformer import build


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 16, gen_len: int = 32, seed: int = 0) -> dict:
    cfg = configs.get(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    max_seq = prompt_len + gen_len
    cache = model.init_cache(batch=batch, max_seq=max_seq)
    step = jax.jit(model.decode_step)

    rng = np.random.RandomState(seed)
    prompt = rng.randint(0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)
    toks = jnp.asarray(prompt)

    # "prefill" by stepping the prompt (simple serving; batched requests share
    # the step); production prefill is the prefill_32k dry-run path
    t0 = time.time()
    out_tokens = []
    logits = None
    for t in range(prompt_len):
        logits, cache = step(params, {"tokens": toks[:, t:t + 1]}, cache,
                             jnp.int32(t))
    for t in range(prompt_len, max_seq):
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(nxt))
        logits, cache = step(params, {"tokens": nxt[:, None]}, cache,
                             jnp.int32(t))
    dt = time.time() - t0
    gen = np.stack(out_tokens, 1)
    tps = batch * gen_len / dt
    print(f"{arch}: generated {gen.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    return {"generated": gen, "tokens_per_s": tps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    a = ap.parse_args()
    serve(a.arch, batch=a.batch, prompt_len=a.prompt_len, gen_len=a.gen_len)


if __name__ == "__main__":
    main()
