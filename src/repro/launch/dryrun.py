import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init). No `from __future__` here for that reason.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the real step function against
ShapeDtypeStruct inputs on the production mesh (no allocation), prints
memory_analysis / cost_analysis, extracts the three roofline terms, and
writes experiments/dryrun/<arch>__<shape>__<mesh>.json.

Step per shape kind (paper-faithful baseline):
  train_4k     -> federated progressive round: mid-stage SmartFreeze step,
                  K local steps then the Eq. 1 pod all-reduce
  prefill_32k  -> full forward, last-position logits
  decode_*     -> one-token serve step against a seq_len KV cache

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""


import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, skip_reason
from repro.core import freezing
from repro.core.output_module import lm_op_abstract
from repro.data.synthetic import input_specs
from repro.dist.sharding import (fsdp_tree_shardings, make_rules, batch_spec)
from repro.launch import mesh as mesh_mod
from repro.launch.roofline import analyze_compiled
from repro.models.transformer import build
from repro.optim import sgd

AXES_LEAF = lambda x: isinstance(x, tuple) and all(
    a is None or isinstance(a, str) for a in x)


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------


def param_shardings(cfg: ArchConfig, mesh, aparams, axes_tree, *,
                    serve_tp: bool = False, opts=frozenset()):
    """FSDP+TP by default. Hillclimb opts (EXPERIMENTS.md §Perf):
    serve_tp   — TP-resident serve params (no per-token weight all-gathers)
    fsdp_out   — FSDP only on weight OUTPUT dims (no contracting-dim shards)
    no_tp      — replicate weights entirely (tiny archs)."""
    rules = make_rules(cfg, mesh, no_tp="no_tp" in opts)
    if serve_tp:
        from repro.dist.sharding import tree_shardings
        import numpy as _np

        model_size = mesh.shape.get("model", 1)
        total = sum(int(_np.prod(l.shape)) * l.dtype.itemsize
                    for l in jax.tree.leaves(aparams))
        if total / model_size < 12 * 2**30:  # fits: TP-resident
            return tree_shardings(mesh, axes_tree, rules, aparams)
    return fsdp_tree_shardings(mesh, axes_tree, rules, aparams,
                               fsdp_axes=("data",),
                               output_dim_only="fsdp_out" in opts)


def cache_shardings(cfg: ArchConfig, mesh, acache, batch: int, *,
                    seq_over_model: bool = False):
    """Structural KV/state cache shardings (see dist/sharding.py doc).

    ``seq_over_model`` (§Perf): when kv-heads cannot shard over "model"
    (GQA kv < 16), shard the cache SEQ dim over "model" instead — removes the
    16x cache replication (llama decode_32k: 34 GiB -> 2.1 GiB per chip);
    attention's softmax/weighted-sum over the sharded seq lower to cheap
    reduction collectives."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    model_size = mesh.shape.get("model", 1)
    batch_ax = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    def leaf_spec(path: Tuple[str, ...], leaf) -> NamedSharding:
        name = path[-1]
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        # layout: stacked caches are [L, B, ...]; shared-attn caches [B, ...]
        b_dim = 1 if nd >= 2 and shape[0] != batch else 0
        if shape[b_dim] == batch and batch % dp == 0 and dp > 1:
            spec[b_dim] = batch_ax
            batch_sharded = True
        else:
            batch_sharded = False
        if name in ("k", "v"):
            s_dim, h_dim = b_dim + 1, b_dim + 2
            if not batch_sharded and "data" in mesh.shape \
                    and shape[s_dim] % mesh.shape["data"] == 0:
                spec[s_dim] = "data"  # flash-decode style seq sharding
            if shape[h_dim] % model_size == 0 and shape[h_dim] >= model_size:
                spec[h_dim] = "model"
            elif seq_over_model and spec[s_dim] is None \
                    and shape[s_dim] % model_size == 0:
                spec[s_dim] = "model"
        elif name == "ckv":
            s_dim, l_dim = b_dim + 1, b_dim + 2
            if not batch_sharded and "data" in mesh.shape \
                    and shape[s_dim] % mesh.shape["data"] == 0:
                spec[s_dim] = "data"
            if shape[l_dim] % model_size == 0:
                spec[l_dim] = "model"
        elif name == "kpe":
            pass  # small shared-head rope cache: replicate
        elif name in ("h", "C"):  # ssm/mlstm state [*, B, H, ...]
            h_dim = b_dim + 1
            if shape[h_dim] % model_size == 0 and shape[h_dim] >= model_size:
                spec[h_dim] = "model"
        elif name == "conv":  # [*, B, k-1, C]
            c_dim = b_dim + 2
            if c_dim < nd and shape[c_dim] % model_size == 0:
                spec[c_dim] = "model"
        return NamedSharding(mesh, P(*spec))

    from repro.models.module import tree_paths

    flat = {path: leaf_spec(path, leaf) for path, leaf in tree_paths(acache)}
    out: Dict = {}
    for path, sh in flat.items():
        node = out
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = sh
    return out


def batch_shardings(cfg: ArchConfig, mesh, specs: Dict, kind: str):
    """Token/frame batch shardings per shape kind."""
    multi = "pod" in mesh.shape
    out = {}
    for k, sds in specs.items():
        nd = len(sds.shape)
        if kind == "train":
            # [pods, local_steps, per_pod_batch, ...]
            spec = [None] * nd
            if multi:
                spec[0] = "pod"
            if sds.shape[2] % mesh.shape["data"] == 0:
                spec[2] = "data"
            out[k] = NamedSharding(mesh, P(*spec))
        else:
            out[k] = batch_spec(mesh, nd)
            dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
            dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
            if sds.shape[0] % dp != 0:  # e.g. long_500k batch=1
                out[k] = NamedSharding(mesh, P(*([None] * nd)))
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def lower_train_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                     stage: Optional[int] = None, local_steps: int = 1,
                     remat: bool = True, vanilla: bool = False,
                     opts=frozenset()):
    """Federated progressive train step (or vanilla full-model when asked)."""
    cfg = dataclasses.replace(cfg, batch_axes=("data",))  # pod consumed by vmap
    model = build(cfg)
    aparams = model.abstract_params()
    axes = model.axes_tree()
    num_pods = mesh.shape.get("pod", 1)
    if vanilla:
        plan = freezing.make_stage_plan(cfg, None)
    else:
        stage = cfg.num_freeze_blocks // 2 if stage is None else stage
        plan = freezing.make_stage_plan(cfg, stage)

    # slicing stacked leaves is not defined on ShapeDtypeStructs — trace the
    # (init ∘ split) composition abstractly instead
    afrozen, aactive = jax.eval_shape(
        lambda: freezing.split_stage_params(
            model, model.init(jax.random.PRNGKey(0)), plan))
    xfrozen, xactive = freezing.split_stage_axes(model, axes, plan)
    if not plan.final:
        aop, xop = lm_op_abstract(cfg, plan.stage)
        aactive["op"] = aop
        xactive["op"] = xop

    sh_frozen = param_shardings(cfg, mesh, afrozen, xfrozen, opts=opts)
    sh_active = param_shardings(cfg, mesh, aactive, xactive, opts=opts)

    pod_param_spec = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*(("pod",) + tuple(s.spec)))) if num_pods > 1
        else NamedSharding(mesh, P(*((None,) + tuple(s.spec)))),
        sh_active, is_leaf=lambda x: isinstance(x, NamedSharding))

    def constrain(podded):
        return jax.tree.map(jax.lax.with_sharding_constraint, podded, pod_param_spec)

    remat_policy = (jax.checkpoint_policies.dots_saveable
                    if "save_dots" in opts else None)
    step = freezing.make_fed_round_step(
        model, plan, sgd(1e-2), num_pods=num_pods, local_steps=local_steps,
        remat=remat, constrain_podded=constrain, remat_policy=remat_policy)

    specs = input_specs(cfg, shape, num_pods=num_pods, local_steps=local_steps)
    sh_batch = batch_shardings(cfg, mesh, specs, "train")
    aweights = jax.ShapeDtypeStruct((num_pods,), jnp.float32)
    sh_w = NamedSharding(mesh, P("pod" if num_pods > 1 else None))

    with jax.set_mesh(mesh):
        jitted = jax.jit(step,
                         in_shardings=(sh_active, sh_frozen, sh_batch, sh_w),
                         out_shardings=(sh_active, None))
        lowered = jitted.lower(aactive, afrozen, specs, aweights)
    return lowered


def lower_prefill_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                       opts=frozenset()):
    model = build(cfg)
    aparams = model.abstract_params()
    axes = model.axes_tree()
    sh_params = param_shardings(cfg, mesh, aparams, axes,
                                serve_tp="serve_tp" in opts, opts=opts)
    specs = input_specs(cfg, shape)
    sh_batch = batch_shardings(cfg, mesh, specs, "prefill")

    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits[:, -1, :]  # next-token logits

    with jax.set_mesh(mesh):
        jitted = jax.jit(prefill, in_shardings=(sh_params, sh_batch),
                         out_shardings=None)
        lowered = jitted.lower(aparams, specs)
    return lowered


def lower_decode_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                      opts=frozenset()):
    model = build(cfg)
    aparams = model.abstract_params()
    axes = model.axes_tree()
    sh_params = param_shardings(cfg, mesh, aparams, axes,
                                serve_tp="serve_tp" in opts, opts=opts)
    B, S = shape.global_batch, shape.seq_len
    acache = jax.eval_shape(lambda: model.init_cache(B, S))
    sh_cache = cache_shardings(cfg, mesh, acache, B,
                               seq_over_model="cache_sm" in opts)
    specs = input_specs(cfg, shape)
    sh_batch = batch_shardings(cfg, mesh, specs, "decode")
    apos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, batch, pos):
        return model.decode_step(params, batch, cache, pos)

    donate = (1,) if "donate" in opts else ()
    with jax.set_mesh(mesh):
        jitted = jax.jit(serve_step,
                         in_shardings=(sh_params, sh_cache, sh_batch, None),
                         out_shardings=(None, sh_cache),
                         donate_argnums=donate)
        lowered = jitted.lower(aparams, acache, specs, apos)
    return lowered


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *, opts=frozenset(), **kw):
    if shape.kind == "train":
        return lower_train_cell(cfg, shape, mesh, opts=opts, **kw)
    if shape.kind == "prefill":
        return lower_prefill_cell(cfg, shape, mesh, opts=opts)
    return lower_decode_cell(cfg, shape, mesh, opts=opts)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             out_dir: str = "experiments/dryrun", verbose: bool = True,
             **kw) -> Dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    skip = skip_reason(cfg, shape)
    result: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if skip:
        result["skipped"] = skip
        _write(out_dir, result)
        return result
    mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, **kw)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_kind}] "
                  f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
            print(" ", mem)
            print(" ", {k: v for k, v in (compiled.cost_analysis() or {}).items()
                        if k in ("flops", "bytes accessed")})
        result.update(analyze_compiled(compiled, mesh, cfg, shape))
        result["lower_s"] = round(t_lower, 1)
        result["compile_s"] = round(t_compile, 1)
        result["ok"] = True
        if os.environ.get("REPRO_SAVE_HLO", "1") == "1":
            import gzip
            os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
            with gzip.open(os.path.join(
                    out_dir, "hlo",
                    f"{arch}__{shape_name}__{mesh_kind}.hlo.gz"), "wt") as fh:
                fh.write(compiled.as_text())
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result["ok"] = False
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_kind}] FAILED: {result['error']}")
    _write(out_dir, result)
    return result


def _write(out_dir: str, result: Dict):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--vanilla", action="store_true",
                    help="full-model step instead of the SmartFreeze stage step")
    ap.add_argument("--stage", type=int, default=None)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", default="", help="comma-separated hillclimb opts")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        archs = [n for n in configs.names()]
        cells = [(a, s.name) for a in archs
                 for s in (SHAPES.values())]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    ok = fail = skip = 0
    for arch, shape in cells:
        for mk in meshes:
            r = run_cell(arch, shape, mk, out_dir=args.out,
                         vanilla=args.vanilla, stage=args.stage,
                         local_steps=args.local_steps,
                         opts=frozenset(o for o in args.opt.split(",") if o))
            if r.get("skipped"):
                skip += 1
            elif r.get("ok"):
                ok += 1
            else:
                fail += 1
    print(f"dry-run complete: {ok} ok, {fail} failed, {skip} skipped")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
