"""Re-derive roofline terms from saved gzipped HLO (no recompile).

Usage: PYTHONPATH=src python -m repro.launch.reanalyze [out_dir]
Rewrites the metric fields of every experiments/dryrun/*.json whose HLO was
saved, using the current roofline parser.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from repro import configs
from repro.configs.base import SHAPES
from repro.core.memory_model import model_flops_6nd
from repro.launch import mesh as mesh_mod
from repro.launch.roofline import hlo_weighted_costs


def reanalyze(out_dir: str = "experiments/dryrun"):
    n = 0
    for jf in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(jf))
        if not r.get("ok"):
            continue
        hf = os.path.join(out_dir, "hlo",
                          f"{r['arch']}__{r['shape']}__{r['mesh']}.hlo.gz")
        if not os.path.exists(hf):
            continue
        hlo = gzip.open(hf, "rt").read()
        w = hlo_weighted_costs(hlo)
        cfg = configs.get(r["arch"])
        shape = SHAPES[r["shape"]]
        n_chips = 512 if r["mesh"] == "multi" else 256
        compute_s = w["flops"] / mesh_mod.PEAK_FLOPS_BF16
        memory_s = w["bytes"] / mesh_mod.HBM_BW
        collective_s = w["collective_bytes"] / mesh_mod.ICI_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": collective_s}
        mf = model_flops_6nd(cfg, shape.global_batch,
                             shape.seq_len if shape.kind in ("train", "prefill") else 1)
        if shape.kind != "train":
            mf /= 3.0
        bound = max(terms.values())
        r.update(per_chip_flops=w["flops"], per_chip_bytes=w["bytes"],
                 collective_bytes=w["collective_bytes"],
                 collective_by_op={k: int(v) for k, v in w["collective_by_op"].items()},
                 compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s,
                 dominant=max(terms, key=terms.get).replace("_s", ""),
                 model_flops_6nd=mf,
                 useful_flops_ratio=(mf / n_chips) / w["flops"] if w["flops"] else None,
                 roofline_fraction=compute_s / bound if bound else None)
        json.dump(r, open(jf, "w"), indent=1)
        n += 1
    print(f"reanalyzed {n} cells")


if __name__ == "__main__":
    reanalyze(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
