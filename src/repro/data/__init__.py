from repro.data.synthetic import (SyntheticVision, SyntheticLM, make_lm_batch,
                                  input_specs)
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.pipeline import ShardedLoader
