"""Synthetic data: learnable vision classification (per-class Gaussian
prototypes over structured images) and LM token streams, plus the
ShapeDtypeStruct ``input_specs`` the dry-run lowers against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


# ---------------------------------------------------------------------------
# Vision (CIFAR-like) — learnable, so FL accuracy trends are real
# ---------------------------------------------------------------------------


@dataclass
class SyntheticVision:
    num_classes: int = 10
    image_size: int = 32
    noise: float = 0.35
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # class prototypes: low-frequency random patterns (conv-learnable)
        base = rng.randn(self.num_classes, 8, 8, 3).astype(np.float32)
        self.protos = np.stack([
            np.kron(base[c], np.ones((4, 4, 1), np.float32))[:self.image_size, :self.image_size]
            for c in range(self.num_classes)])

    def sample(self, n: int, labels: Optional[np.ndarray] = None,
               seed: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(seed)
        y = labels if labels is not None else rng.randint(0, self.num_classes, n)
        x = self.protos[y] + self.noise * rng.randn(n, self.image_size,
                                                    self.image_size, 3).astype(np.float32)
        return {"x": x.astype(np.float32), "y": y.astype(np.int32)}


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------


@dataclass
class SyntheticLM:
    vocab_size: int
    seed: int = 0

    def sample(self, batch: int, seq: int, seed: int = 0) -> Dict[str, np.ndarray]:
        """Zipf-distributed tokens with a learnable bigram structure."""
        rng = np.random.RandomState(seed)
        v = self.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(batch, seq + 1), p=probs).astype(np.int32)
        # inject determinism: every even position repeats (t-1 + 1) mod v
        toks[:, 2::2] = (toks[:, 1:-1:2] + 1) % v
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}


def make_lm_batch(cfg: ArchConfig, batch: int, seq: int, seed: int = 0) -> Dict:
    """Concrete (numpy) batch for smoke tests, modality-aware."""
    rng = np.random.RandomState(seed)
    if cfg.modality == "audio_stub":
        return {"frames": rng.randn(batch, seq, cfg.frontend_dim).astype(np.float32),
                "labels": rng.randint(0, cfg.vocab_size, (batch, seq)).astype(np.int32)}
    if cfg.modality == "vision_stub":
        nt = cfg.num_image_tokens
        st = seq - nt
        return {"tokens": rng.randint(0, cfg.vocab_size, (batch, st)).astype(np.int32),
                "patches": rng.randn(batch, nt, cfg.frontend_dim).astype(np.float32),
                "labels": rng.randint(0, cfg.vocab_size, (batch, st)).astype(np.int32)}
    d = SyntheticLM(cfg.vocab_size).sample(batch, seq, seed)
    return d


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *,
                num_pods: int = 1, local_steps: int = 1) -> Dict:
    """Abstract inputs for one step at the given assigned shape.

    train: federated-round layout [num_pods, local_steps, per_pod_batch, ...]
    prefill: [batch, seq] tokens; decode: [batch, 1] token + pos scalar
    (the KV cache is built separately — it is state, not input).
    """
    f32 = jnp.float32
    i32 = jnp.int32
    B, S = shape.global_batch, shape.seq_len

    def tok_specs(b, lead=()):
        if cfg.modality == "audio_stub":
            return {"frames": jax.ShapeDtypeStruct(lead + (b, S, cfg.frontend_dim), f32),
                    "labels": jax.ShapeDtypeStruct(lead + (b, S), i32)}
        if cfg.modality == "vision_stub":
            nt = cfg.num_image_tokens
            return {"tokens": jax.ShapeDtypeStruct(lead + (b, S - nt), i32),
                    "patches": jax.ShapeDtypeStruct(lead + (b, nt, cfg.frontend_dim), f32),
                    "labels": jax.ShapeDtypeStruct(lead + (b, S - nt), i32)}
        return {"tokens": jax.ShapeDtypeStruct(lead + (b, S), i32),
                "labels": jax.ShapeDtypeStruct(lead + (b, S), i32)}

    if shape.kind == "train":
        per_pod = B // num_pods
        return tok_specs(per_pod, lead=(num_pods, local_steps))
    if shape.kind == "prefill":
        return tok_specs(B)
    # decode: one new token against a seq_len cache
    if cfg.modality == "audio_stub":
        raise ValueError("encoder-only arch has no decode step")
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
