"""Client data partitioning: IID and Dirichlet(alpha) non-IID (paper §V-A)."""
from __future__ import annotations

from typing import List

import numpy as np


def iid_partition(labels: np.ndarray, num_clients: int, seed: int = 0
                  ) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(s) for s in np.array_split(idx, num_clients)]


def dirichlet_partition(labels: np.ndarray, num_clients: int, alpha: float = 1.0,
                        seed: int = 0, min_per_client: int = 2) -> List[np.ndarray]:
    """Label-skew non-IID: per class, split indices by Dirichlet(alpha) shares
    (smaller alpha = more skew; paper uses alpha=1)."""
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    shares = [[] for _ in range(num_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        p = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(p) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            shares[cid].extend(part.tolist())
    # ensure every client has a floor of samples
    pool = [i for s in shares for i in s]
    for cid in range(num_clients):
        while len(shares[cid]) < min_per_client:
            shares[cid].append(pool[rng.randint(len(pool))])
    return [np.sort(np.asarray(s)) for s in shares]


def label_distribution(labels: np.ndarray, parts: List[np.ndarray],
                       num_classes: int) -> np.ndarray:
    """[num_clients, num_classes] empirical label histogram (for Fig.6/9)."""
    out = np.zeros((len(parts), num_classes))
    for i, p in enumerate(parts):
        for c in range(num_classes):
            out[i, c] = np.sum(labels[p] == c)
    return out / np.maximum(out.sum(1, keepdims=True), 1)
