"""Host-side data pipeline: deterministic sharded batching with prefetch.

On a real multi-host TPU deployment each host feeds its local devices; here
the loader yields globally-consistent batches and shards them onto the mesh
with ``jax.device_put`` + NamedSharding (the same call pattern works 1-host
or N-host via jax.make_array_from_process_local_data).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class ShardedLoader:
    def __init__(self, sample_fn: Callable[[int], Dict[str, np.ndarray]], *,
                 sharding=None, prefetch: int = 2):
        """sample_fn(step) -> batch dict of numpy arrays."""
        self.sample_fn = sample_fn
        self.sharding = sharding
        self.prefetch = prefetch
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread: Optional[threading.Thread] = None

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            batch = self.sample_fn(step)
            if self.sharding is not None:
                batch = jax.tree.map(
                    lambda x: jax.device_put(x, self.sharding), batch)
            try:
                self._q.put(batch, timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict]:
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        try:
            while True:
                yield self._q.get()
        finally:
            self.stop()

    def stop(self):
        self._stop.set()
