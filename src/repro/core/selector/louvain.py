"""Louvain modularity maximization (Blondel et al. 2008), from scratch.

Operates on a dense non-negative weight matrix (client similarity). One level
of local moving + graph aggregation, repeated until modularity stops
improving. Cross-checked against networkx.louvain_communities in tests.
"""
from __future__ import annotations

from typing import List

import numpy as np


def louvain(W: np.ndarray, *, resolution: float = 1.0, seed: int = 0,
            max_levels: int = 10) -> List[List[int]]:
    """Returns communities as lists of original node indices."""
    n = W.shape[0]
    W = np.asarray(W, np.float64).copy()
    np.fill_diagonal(W, 0.0)
    W = np.maximum(W, 0.0)  # Louvain needs non-negative weights
    membership = list(range(n))  # original node -> community label
    node_groups = [[i] for i in range(n)]  # current super-node -> original nodes
    rng = np.random.RandomState(seed)

    for _ in range(max_levels):
        labels, improved = _one_level(W, resolution, rng)
        uniq = sorted(set(labels))
        if not improved or len(uniq) == W.shape[0]:
            break
        # aggregate — KEEP self-loops: intra-community weight must stay in the
        # supernode degree or the next level over-merges
        remap = {c: k for k, c in enumerate(uniq)}
        labels = [remap[c] for c in labels]
        m = len(uniq)
        new_groups: List[List[int]] = [[] for _ in range(m)]
        for sn, lab in enumerate(labels):
            new_groups[lab].extend(node_groups[sn])
        Wn = np.zeros((m, m))
        for i in range(W.shape[0]):
            for j in range(W.shape[0]):
                Wn[labels[i], labels[j]] += W[i, j]
        node_groups = new_groups
        W = Wn
        if m <= 1:
            break
    for k, grp in enumerate(node_groups):
        for orig in grp:
            membership[orig] = k
    out: List[List[int]] = [[] for _ in range(len(node_groups))]
    for orig, c in enumerate(membership):
        out[c].append(orig)
    return [sorted(c) for c in out if c]


def _one_level(W: np.ndarray, resolution: float, rng) -> tuple:
    n = W.shape[0]
    deg = W.sum(axis=1)
    two_m = deg.sum()
    if two_m <= 0:
        return list(range(n)), False
    labels = np.arange(n)
    comm_deg = deg.copy()  # total degree per community
    improved_any = False
    for _ in range(20):
        moved = False
        order = rng.permutation(n)
        for v in order:
            c_old = labels[v]
            comm_deg[c_old] -= deg[v]
            # weights from v to each community
            w_to = {}
            for u in range(n):
                if u != v and W[v, u] > 0:
                    w_to[labels[u]] = w_to.get(labels[u], 0.0) + W[v, u]
            best_c, best_gain = c_old, w_to.get(c_old, 0.0) - \
                resolution * comm_deg[c_old] * deg[v] / two_m
            for c, w in w_to.items():
                gain = w - resolution * comm_deg[c] * deg[v] / two_m
                if gain > best_gain + 1e-12:
                    best_gain, best_c = gain, c
            labels[v] = best_c
            comm_deg[best_c] += deg[v]
            if best_c != c_old:
                moved = True
                improved_any = True
        if not moved:
            break
    return list(labels), improved_any


def modularity(W: np.ndarray, communities: List[List[int]],
               resolution: float = 1.0) -> float:
    W = np.asarray(W, np.float64).copy()
    np.fill_diagonal(W, 0.0)
    W = np.maximum(W, 0.0)
    deg = W.sum(axis=1)
    two_m = deg.sum()
    if two_m <= 0:
        return 0.0
    q = 0.0
    for comm in communities:
        idx = np.asarray(comm)
        q += W[np.ix_(idx, idx)].sum() / two_m
        q -= resolution * (deg[idx].sum() / two_m) ** 2
    return q
