"""Client similarity from output-layer gradients (paper Eq. 8) and its
population-scale sketch approximation.

Each client trains ONLY the global model's output layer for a few steps on
local data and reports that gradient vector once (memory-cheap: no backprop
through the body). Cosine similarity between these vectors tracks label
distribution similarity — the basis for RL-CD community detection.

The dense N x N ``similarity_matrix`` is the small-N oracle. At population
scale the same signal is carried by each client's *label distribution*
(which is what the output-layer gradient tracks): clients report a
``sketch_dim``-sized count-sketch of their normalized label histogram, and
similarity is evaluated lazily in row blocks (tiled jnp matmul + per-row
``lax.top_k``) so only the top-m neighbor lists — O(N * m), not O(N^2) —
ever materialize. Those neighbor lists feed the vectorized label
propagation in rlcd.py.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def output_layer_gradient(loss_head_fn: Callable, head_params, data) -> np.ndarray:
    """Gradient of the loss wrt output-layer params only, flattened."""
    g = jax.grad(loss_head_fn)(head_params, data)
    return np.concatenate([np.asarray(x, np.float32).ravel()
                           for x in jax.tree.leaves(g)])


def similarity_matrix(grads: Dict[int, np.ndarray]) -> np.ndarray:
    """Omega[i, j] = cosine similarity of client gradient vectors (Eq. 8)."""
    ids = sorted(grads)
    G = np.stack([grads[i] for i in ids]).astype(np.float64)
    norms = np.linalg.norm(G, axis=1, keepdims=True)
    G = G / np.maximum(norms, 1e-12)
    return G @ G.T


# ---------------------------------------------------------------------------
# Hashed label-distribution sketches
# ---------------------------------------------------------------------------


def sketch_projection(num_classes: int, sketch_dim: int, seed: int = 0, *,
                      n_hashes: int = 4) -> np.ndarray:
    """Sparse signed hash projection [num_classes, sketch_dim]: each class
    hashes to ``n_hashes`` signed buckets (sparse Johnson-Lindenstrauss),
    so sketching is one sparse matmul and sketch cosine approximates
    histogram cosine. A single hash (classic count-sketch) makes a bucket
    collision between two classes catastrophic — their histograms become
    fully (anti-)correlated; with ``n_hashes`` independent buckets the
    distortion of any pair is averaged down by 1/n_hashes."""
    rng = np.random.RandomState(seed)
    P = np.zeros((num_classes, sketch_dim), np.float32)
    for _ in range(n_hashes):
        bucket = rng.randint(0, sketch_dim, size=num_classes)
        sign = rng.choice(np.asarray([-1.0, 1.0], np.float32),
                          size=num_classes)
        P[np.arange(num_classes), bucket] += sign / np.sqrt(n_hashes)
    return P


def label_sketches(histograms: np.ndarray, projection: np.ndarray
                   ) -> jnp.ndarray:
    """[N, num_classes] label histograms -> [N, sketch_dim] device sketches
    of the normalized label distributions."""
    h = np.asarray(histograms, np.float32)
    h = h / np.maximum(h.sum(axis=1, keepdims=True), 1.0)
    return jnp.asarray(h) @ jnp.asarray(projection)


@partial(jax.jit, static_argnames=("m",))
def _block_topm(block, vecs_t, row_offset, *, m):
    sims = block @ vecs_t                               # [B, N] tile
    b = block.shape[0]
    rows = jnp.arange(b)
    sims = sims.at[rows, row_offset + rows].set(-jnp.inf)   # mask self
    w, idx = jax.lax.top_k(sims, m)
    return idx.astype(jnp.int32), w


def topm_neighbors(vecs, m: int, *, block_rows: int = 4096,
                   max_tile_bytes: int = 128 << 20
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-m cosine neighbors per row without materializing N x N: the
    similarity matrix is computed one [block_rows, N] tile at a time and
    immediately reduced by ``lax.top_k``. Returns ([N, m] neighbor indices,
    [N, m] cosine weights); at most two block shapes are traced.

    ``block_rows`` is a ceiling — the effective block shrinks so one f32
    tile stays under ``max_tile_bytes`` (otherwise a 4096-row block at
    N=100k would transiently allocate ~1.6 GB, defeating the O(N*m)
    memory claim)."""
    vecs = jnp.asarray(vecs, jnp.float32)
    n = vecs.shape[0]
    m = min(m, n - 1)
    block_rows = max(1, min(block_rows, max_tile_bytes // max(4 * n, 1)))
    norms = jnp.linalg.norm(vecs, axis=1, keepdims=True)
    unit = vecs / jnp.maximum(norms, 1e-12)
    unit_t = unit.T
    idx_blocks, w_blocks = [], []
    for lo in range(0, n, block_rows):
        hi = min(lo + block_rows, n)
        idx_b, w_b = _block_topm(unit[lo:hi], unit_t, jnp.int32(lo), m=m)
        idx_blocks.append(idx_b)
        w_blocks.append(w_b)
    return jnp.concatenate(idx_blocks), jnp.concatenate(w_blocks)
