"""Client similarity from output-layer gradients (paper Eq. 8).

Each client trains ONLY the global model's output layer for a few steps on
local data and reports that gradient vector once (memory-cheap: no backprop
through the body). Cosine similarity between these vectors tracks label
distribution similarity — the basis for RL-CD community detection.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np


def output_layer_gradient(loss_head_fn: Callable, head_params, data) -> np.ndarray:
    """Gradient of the loss wrt output-layer params only, flattened."""
    g = jax.grad(loss_head_fn)(head_params, data)
    return np.concatenate([np.asarray(x, np.float32).ravel()
                           for x in jax.tree.leaves(g)])


def similarity_matrix(grads: Dict[int, np.ndarray]) -> np.ndarray:
    """Omega[i, j] = cosine similarity of client gradient vectors (Eq. 8)."""
    ids = sorted(grads)
    G = np.stack([grads[i] for i in ids]).astype(np.float64)
    norms = np.linalg.norm(G, axis=1, keepdims=True)
    G = G / np.maximum(norms, 1e-12)
    return G @ G.T
