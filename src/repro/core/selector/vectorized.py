"""Population-scale participant selection as jitted array programs.

The list-based ``ParticipantSelector`` (selection.py) walks Python lists and
dicts per round — O(N) interpreter work plus an O(N^2) community/pool walk —
which caps the simulator at a few thousand clients. This module re-implements
the same per-stage policy (paper §IV-C, Eqs. 11-14) over a
``ClientPopulation`` structure-of-arrays so the per-round control path is a
handful of O(N) jitted kernels:

  Eq. 12 memory filter      ``memory_bytes >= mem_required`` mask
  Eq. 14 feasibility        masked ``sum`` of the eligibility mask
  Eq. 11 utility            ``loss_sum - lam * stage_time`` (vectorized)
  community coverage        per-community eligible counts via ``segment_sum``
  within-community pick     gumbel-top-k: utility perturbed by Gumbel noise
                            scaled by ``epsilon``; per-community maxima via
                            ``segment_max`` + lowest-index ``segment_min``
                            tie-break, one pass per round-robin sweep

Round-robin coverage itself (which community contributes the next slot,
including the list path's pool-exhaustion re-permutes) depends only on the
per-community eligible COUNTS, never on which members win — so it runs as an
O(C) host simulation sharing the exact ``numpy.random.RandomState`` stream
of the list selector, while all O(N) member-level work stays on device.
With ``epsilon=0`` the vectorized picks are identical to
``ParticipantSelector`` (cross-checked in tests) up to float32 utility
resolution: population arrays are f32, so two clients whose Eq. 11
utilities differ by less than f32 epsilon resolve as a tie (lowest index
wins) where the list path's float64 arithmetic would order them. With
``epsilon>0`` the Gumbel perturbation is the population-scale relaxation
of the epsilon-greedy bandit (exploration mass spreads over near-top
utilities instead of an explicit stale-client queue).

Avoiding a global ``argsort`` is deliberate: XLA's CPU sort costs ~90 ms at
N=100k, whereas the segment-op sweeps here are linear scans (a few ms).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selector.bandit import mix_seed
from repro.core.selector.selection import (ClientInfo, InfeasibleStageError,
                                           ParticipantSelector)


# ---------------------------------------------------------------------------
# Structure-of-arrays population
# ---------------------------------------------------------------------------


@dataclass
class ClientPopulation:
    """Fleet state as device-resident arrays (one row per client).

    ``client_ids`` stays on host (external identity only); every per-round
    quantity the selector reads is a jnp array so selection never walks a
    Python list. ``community_id`` is in ``[0, n_communities]`` where the
    value ``n_communities`` is the "unassigned" bucket — mirrored from the
    list path, where clients outside every fitted community are never picked
    by the community round-robin.
    """

    client_ids: np.ndarray            # [N] host-side external ids
    memory_bytes: jnp.ndarray         # [N] f32 — device memory capacity
    capability: jnp.ndarray           # [N] f32 — c_i (FLOP/s)
    num_samples: jnp.ndarray          # [N] i32 — |D_i|
    loss_sum: jnp.ndarray             # [N] f32 — I_{t,i} (Eq. 9)
    community_id: jnp.ndarray = None  # [N] i32
    n_communities: int = 1
    last_seen: jnp.ndarray = None     # [N] i32 round last selected (-1 never)
    ef_residual_norm: jnp.ndarray = None  # [N] f32 error-feedback residual norms
    _stage_time: Optional[tuple] = field(default=None, repr=False)  # (key, [N])

    def __post_init__(self):
        n = self.n
        if self.community_id is None:
            self.community_id = jnp.zeros(n, jnp.int32)
        if self.last_seen is None:
            self.last_seen = jnp.full(n, -1, jnp.int32)
        if self.ef_residual_norm is None:
            self.ef_residual_norm = jnp.zeros(n, jnp.float32)

    @property
    def n(self) -> int:
        return len(self.client_ids)

    @classmethod
    def from_infos(cls, infos, *, community_id=None, n_communities: int = 1
                   ) -> "ClientPopulation":
        """Build from ``{cid: ClientInfo}`` (sorted by client id, so array
        index order matches the list selector's sorted-community pool order
        and tie-breaks agree) or a sequence (order preserved — callers that
        need a specific candidate order, e.g. the adapter mirroring the
        bandit's insertion-order semantics, pass a pre-ordered list)."""
        if isinstance(infos, dict):
            infos = [infos[c] for c in sorted(infos)]
        else:
            infos = list(infos)
        ids = np.asarray([c.client_id for c in infos])
        return cls(
            client_ids=ids,
            memory_bytes=jnp.asarray([c.memory_bytes for c in infos],
                                     jnp.float32),
            capability=jnp.asarray([c.capability for c in infos], jnp.float32),
            num_samples=jnp.asarray([c.num_samples for c in infos], jnp.int32),
            loss_sum=jnp.asarray([c.loss_sum for c in infos], jnp.float32),
            community_id=(None if community_id is None
                          else jnp.asarray(community_id, jnp.int32)),
            n_communities=n_communities)

    def shard(self, mesh) -> "ClientPopulation":
        """Copy with every per-client column placed along ``mesh``'s
        ``"clients"`` axis, so the selection / admission kernels
        (``_population_stats``, ``_tier_admission``) run SPMD over the same
        placement the sharded round engine trains on — one fleet layout
        from selection through aggregation.

        Divisibility fallback (same discipline as ``dist.sharding
        .make_rules``): when N does not divide the client-axis size the
        columns are replicated instead — identical results, no
        distribution. The stage-time memo is dropped so it recomputes on
        the new placement."""
        from repro.dist.sharding import shard_client_arrays
        cols = shard_client_arrays(
            mesh, (self.memory_bytes, self.capability, self.num_samples,
                   self.loss_sum, self.community_id, self.last_seen,
                   self.ef_residual_norm))
        import dataclasses as _dc
        return _dc.replace(
            self, memory_bytes=cols[0], capability=cols[1],
            num_samples=cols[2], loss_sum=cols[3], community_id=cols[4],
            last_seen=cols[5], ef_residual_norm=cols[6], _stage_time=None)

    def stage_time(self, flops_per_sample: float = 1.0, rho: float = 1.0
                   ) -> jnp.ndarray:
        """Eq. 6 over the population via the shared vectorized time kernel
        (``core.time_model.stage_times_vec``); the default unit-FLOPs form
        is the selection heuristic t_t^i = |D_i| / c_i. Memoized on device
        per (flops_per_sample, rho) — per-stage FLOPs recompute correctly."""
        key = (float(flops_per_sample), float(rho))
        if self._stage_time is None or self._stage_time[0] != key:
            from repro.core.time_model import stage_times_vec
            self._stage_time = (key, stage_times_vec(
                jnp.float32(flops_per_sample), self.num_samples,
                self.capability, jnp.float32(rho)))
        return self._stage_time[1]

    def set_communities(self, community_id, n_communities: int):
        self.community_id = jnp.asarray(community_id, jnp.int32)
        self.n_communities = int(n_communities)

    def update_loss_sums(self, idx, values):
        """Scatter fresh I_{t,i} for the clients trained this round."""
        self.loss_sum = self.loss_sum.at[jnp.asarray(idx)].set(
            jnp.asarray(values, jnp.float32))


# ---------------------------------------------------------------------------
# Jitted kernels (all O(N); no global sort — see module docstring)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_comm",))
def _population_stats(memory_bytes, stage_time, loss_sum, community_id,
                      gumbel, mem_required, lam, tau, *, n_comm):
    """Eqs. 11/12/14 + per-community coverage counts in one dispatch.

    Returns (score, elig, per-community eligible counts, n_eligible) where
    ``score`` is the (optionally Gumbel-perturbed) utility, ``-inf`` on
    ineligible rows. ``tau = epsilon * temperature``; the noise is scaled by
    the masked utility std so exploration strength is unit-free.
    """
    elig = memory_bytes >= mem_required                          # Eq. 12
    util = loss_sum - lam * stage_time                           # Eq. 11
    n_e = jnp.maximum(jnp.sum(elig), 1).astype(jnp.float32)
    mu = jnp.sum(jnp.where(elig, util, 0.0)) / n_e
    var = jnp.sum(jnp.where(elig, (util - mu) ** 2, 0.0)) / n_e
    score = util + tau * jnp.sqrt(var + 1e-12) * gumbel
    score = jnp.where(elig, score, -jnp.inf)
    counts = jax.ops.segment_sum(elig.astype(jnp.int32), community_id,
                                 num_segments=n_comm)
    return score, elig, counts, jnp.sum(elig)                    # Eq. 14


@partial(jax.jit, static_argnames=("n_comm",))
def _quota_pick(score, community_id, quotas, qmax, *, n_comm):
    """Pick the top-``quotas[c]`` members of every community by score.

    One sweep per rank level: ``segment_max`` finds each community's current
    best, ``segment_min`` over indices breaks score ties toward the lowest
    index (== the list selector's stable pool order), winners are masked to
    ``-inf`` and the sweep repeats. Runs ``qmax = max(quotas)`` sweeps via
    ``lax.while_loop`` — O(N * qmax) with no sort.

    Returns (picked mask [N], sweep index each pick happened at [N]).
    """
    n = score.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)

    def body(carry):
        t, sc, picked, sweep_of = carry
        seg_best = jax.ops.segment_max(sc, community_id, num_segments=n_comm)
        live = ((sc == seg_best[community_id]) & (quotas[community_id] > t)
                & jnp.isfinite(sc))
        winner = jax.ops.segment_min(jnp.where(live, idx, n), community_id,
                                     num_segments=n_comm)
        is_winner = live & (winner[community_id] == idx)
        return (t + 1, jnp.where(is_winner, -jnp.inf, sc),
                picked | is_winner, jnp.where(is_winner, t, sweep_of))

    init = (jnp.int32(0), score, jnp.zeros(n, bool),
            jnp.full(n, -1, jnp.int32))
    _, _, picked, sweep_of = jax.lax.while_loop(lambda c: c[0] < qmax, body,
                                                init)
    return picked, sweep_of


@partial(jax.jit, static_argnames=("k",))
def _topk_pick(score, *, k):
    """Single-community fast path: plain top-k (lax.top_k is stable — equal
    scores resolve to the lower index, matching the list bandit's sort)."""
    vals, idx = jax.lax.top_k(score, k)
    return idx, jnp.isfinite(vals)


@jax.jit
def _mask_to_community(score, community_id):
    """Silence rows outside community 0 (i.e. the unassigned bucket when a
    single community is fitted)."""
    return jnp.where(community_id == 0, score, -jnp.inf)


@jax.jit
def _tier_admission(memory_bytes, stage_bytes, tier_cache_bytes):
    """Eq. 12 run once per feature-cache tier, vectorized: ``fits[t, i]``
    iff client i's memory covers the stage requirement plus its shard's
    cache at ladder tier t. Returns [N] i32 — the FIRST (most exact) tier
    that fits, -1 when even the smallest tier is declined."""
    fits = memory_bytes[None, :] >= stage_bytes + tier_cache_bytes
    first = jnp.argmax(fits, axis=0).astype(jnp.int32)
    return jnp.where(jnp.any(fits, axis=0), first, jnp.int32(-1))


def assign_cache_tiers(pop: "ClientPopulation", stage_bytes: float,
                       per_sample_bytes: Sequence[float]) -> np.ndarray:
    """Population-scale feature-cache admission ladder (the vectorized twin
    of ``SmartFreezeServer._cache_plan`` / ``memory_model.cache_tier_ladder``).

    ``per_sample_bytes[t]`` is the cache cost per local sample at ladder
    tier t (e.g. ``cnn_feature_cache_bytes(model, stage, 1, image_size,
    dtype)`` — cache bytes are linear in shard size, int8 scale vectors
    included, so the per-sample rate is exact). One O(T*N) kernel dispatch;
    returns an [N] host array of ladder indices (-1 = cache declined)."""
    rates = jnp.asarray(np.asarray(per_sample_bytes, np.float32))[:, None]
    cache = rates * pop.num_samples.astype(jnp.float32)[None, :]
    return np.asarray(_tier_admission(pop.memory_bytes,
                                      jnp.float32(stage_bytes), cache))


# ---------------------------------------------------------------------------
# Host-side round-robin quota simulation (exact list-path mirror)
# ---------------------------------------------------------------------------


def _roundrobin_quotas(sizes: np.ndarray, k: int, rng) -> tuple:
    """Replay ``ParticipantSelector.select``'s community round-robin on pool
    SIZES only (O(C + k) host work). Which community fills each slot depends
    only on eligible counts and the RandomState stream, never on member
    identity — so this reproduces the list path's pick schedule exactly,
    including mid-draw pool-exhaustion re-permutes.

    Returns (quota per pool [len(sizes)], pick schedule [(pool, rank), ...]).
    """
    total_avail = int(sizes.sum())
    k_eff = min(k, total_avail)
    pools = [i for i in range(len(sizes)) if sizes[i] > 0]
    taken = np.zeros(len(sizes), np.int64)
    order = rng.permutation(len(pools)) if pools else np.empty(0, np.int64)
    schedule: List[tuple] = []
    ci = 0
    while len(schedule) < k_eff and pools:
        pool = pools[order[ci % len(pools)] % len(pools)]
        if taken[pool] < sizes[pool]:
            schedule.append((pool, int(taken[pool])))
            taken[pool] += 1
        else:
            pools = [p for p in pools if taken[p] < sizes[p]]
            order = rng.permutation(len(pools)) if pools else order
        ci += 1
    return taken, schedule


# ---------------------------------------------------------------------------
# Selector
# ---------------------------------------------------------------------------


@dataclass
class VectorizedSelector:
    """Drop-in ``ParticipantSelector`` replacement backed by array kernels.

    Two entry points:

      * ``select(clients_dict, k, mem_required=..., stage_time_fn=...)`` —
        the list-selector contract (used by ``SmartFreezeServer``): builds a
        throwaway ``ClientPopulation`` per call. With ``epsilon=0`` it
        returns byte-identical picks to ``ParticipantSelector`` for the same
        seed (regression-tested); use it as the small-N cross-check.
      * ``select_arrays(population, k, mem_required=..., round_idx=...)`` —
        the population-scale hot path: arrays stay resident on device across
        rounds, each call costs two O(N) kernel dispatches plus an O(C) host
        quota replay.

    ``phi`` gates Eq. 14 feasibility exactly like the list path (raises
    ``InfeasibleStageError`` on the memory-eligible count, before community
    assignment is consulted).
    """

    lam: float = 1e-3                 # lambda in Eq. 11
    epsilon: float = 0.2
    phi: int = 2                      # Eq. 14 minimum eligible clients
    seed: int = 0
    temperature: float = 1.0          # gumbel-top-k softness (eps>0 only)
    _round: int = 0
    _communities: Optional[List[List[int]]] = None

    # ----- setup -----

    def fit_communities(self, similarity: np.ndarray) -> List[List[int]]:
        """Small-N oracle path: dense RL-CD, same as the list selector."""
        from repro.core.selector.rlcd import rlcd_communities
        self._communities = rlcd_communities(np.asarray(similarity),
                                             seed=self.seed)
        return self._communities

    def fit_communities_sketch(self, label_histograms: np.ndarray, *,
                               sketch_dim: int = 64, num_neighbors: int = 8,
                               n_iter: int = 30, block_rows: int = 4096
                               ) -> np.ndarray:
        """Population-scale path: hashed label-distribution sketches + tiled
        similarity + vectorized label propagation (see rlcd.py). Returns the
        per-row community id array (also retained for ``select_arrays`` via
        ``attach_to``-style use: pass it to ``ClientPopulation.set_communities``)."""
        from repro.core.selector.rlcd import sketch_communities
        comm_id, n_comm = sketch_communities(
            label_histograms, sketch_dim=sketch_dim,
            num_neighbors=num_neighbors, n_iter=n_iter, seed=self.seed,
            block_rows=block_rows)
        self._communities = [np.flatnonzero(comm_id == c).tolist()
                             for c in range(n_comm)]
        return comm_id

    # ----- checkpoint/resume (fl/sim.py serializes through these) -----

    def state_dict(self) -> Dict[str, np.ndarray]:
        """Round counter + fitted communities as arrays — everything a
        resumed run needs to continue the per-round ``mix_seed`` RNG streams
        and community round-robin pick-identically."""
        from repro.checkpoint.ckpt import pack_ragged
        out: Dict[str, np.ndarray] = {"round": np.asarray([self._round],
                                                          np.int64)}
        if self._communities:
            ragged = pack_ragged(self._communities)
            out["comm_flat"] = ragged["flat"]
            out["comm_offsets"] = ragged["offsets"]
        return out

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        from repro.checkpoint.ckpt import unpack_ragged
        self._round = int(np.asarray(state["round"])[0])
        if "comm_flat" in state:
            self._communities = unpack_ragged(
                {"flat": state["comm_flat"],
                 "offsets": state["comm_offsets"]})

    # ----- feature-cache tier admission (Eq. 12 per tier) -----

    def cache_admission(self, pop: ClientPopulation, *, stage_bytes: float,
                        per_sample_bytes: Sequence[float],
                        tiers: Sequence[str] = ("f32", "fp16", "int8")
                        ) -> Dict[int, Optional[str]]:
        """Tier granted per client id (None = recompute): the vectorized
        form of the server's admission ladder, one kernel over the resident
        population instead of an O(N) host walk. ``per_sample_bytes`` and
        ``tiers`` align (most exact first)."""
        idx = assign_cache_tiers(pop, stage_bytes, per_sample_bytes)
        return {int(cid): (tiers[i] if i >= 0 else None)
                for cid, i in zip(pop.client_ids, idx)}

    # ----- population-scale hot path -----

    def select_arrays(self, pop: ClientPopulation, k: int, *,
                      mem_required: float, round_idx: Optional[int] = None,
                      stage_time: Optional[jnp.ndarray] = None,
                      round_robin: Optional[bool] = None) -> np.ndarray:
        """One round of selection over a resident population.

        Returns row indices into ``pop`` in pick order. Host syncs: the
        [C]-sized eligible counts (for the quota replay) and the final picks.

        ``round_robin`` forces the community round-robin schedule even for a
        single fitted community (the list path's behavior whenever
        ``fit_communities`` ran); the default uses it iff ``n_communities >
        1`` and otherwise mirrors the bandit fast path — top-k by score,
        except that ``k >= #eligible`` returns every eligible client in
        ascending index order (``UtilBandit.pick``'s early return).
        """
        # the internal round counter is committed only AFTER the Eq. 14
        # feasibility check: the list selector raises before its bandit's
        # next_round(), so a caught InfeasibleStageError must not
        # desynchronize the two implementations' RNG streams
        commit_round = round_idx is None
        if commit_round:
            round_idx = self._round
        n, n_comm = pop.n, pop.n_communities
        tau = float(self.epsilon) * float(self.temperature)
        if self.epsilon > 0:
            key = jax.random.PRNGKey(mix_seed(self.seed, round_idx + 1))
            gumbel = jax.random.gumbel(key, (n,), jnp.float32)
        else:
            gumbel = jnp.zeros(n, jnp.float32)
        # community ids may include the "unassigned" bucket n_comm
        score, _, counts, n_elig = _population_stats(
            pop.memory_bytes,
            pop.stage_time() if stage_time is None else stage_time,
            pop.loss_sum, pop.community_id, gumbel,
            jnp.float32(mem_required), jnp.float32(self.lam),
            jnp.float32(tau), n_comm=n_comm + 1)
        n_elig = int(n_elig)                      # host sync #1 (Eq. 14)
        if n_elig < self.phi:
            raise InfeasibleStageError(
                f"only {n_elig} clients fit {mem_required / 2**20:.0f} MiB "
                f"(phi={self.phi}) — repartition blocks or lower batch size")
        if commit_round:
            self._round += 1
        sizes = np.asarray(counts)[:n_comm]       # unassigned bucket excluded
        rng = np.random.RandomState(mix_seed(self.seed, round_idx + 1))
        if round_robin is None:
            round_robin = n_comm > 1
        if n_comm == 1 and not round_robin:
            # no communities fitted: the bandit fast path. The unassigned
            # bucket cannot exist here, but mask it anyway for safety.
            k_eff = min(k, int(sizes[0]))
            if k_eff == 0:
                return np.empty(0, np.int64)
            in_comm = _mask_to_community(score, pop.community_id)
            idx, valid = _topk_pick(in_comm, k=min(k, n))
            sel = np.asarray(idx)[np.asarray(valid)][:k_eff]
            if k_eff == int(sizes[0]):
                # k covers every eligible client: the list path's
                # ``bandit.pick`` early-returns the candidates in their
                # original (ascending-index) order, not by score
                sel = np.sort(sel)
            pop.last_seen = pop.last_seen.at[jnp.asarray(sel)].set(round_idx)
            return sel.astype(np.int64)
        quotas, schedule = _roundrobin_quotas(sizes, k, rng)
        if not schedule:
            return np.empty(0, np.int64)
        quotas_dev = jnp.asarray(np.concatenate([quotas, [0]]), jnp.int32)
        picked, sweep_of = _quota_pick(score, pop.community_id, quotas_dev,
                                       jnp.int32(quotas.max()),
                                       n_comm=n_comm + 1)
        picked = np.asarray(picked)               # host sync #2 (the picks)
        sweep_of = np.asarray(sweep_of)
        comm = np.asarray(pop.community_id)
        sel_rows = np.flatnonzero(picked)
        by_slot = {(int(comm[i]), int(sweep_of[i])): int(i) for i in sel_rows}
        sel = np.asarray([by_slot[(c, t)] for c, t in schedule], np.int64)
        pop.last_seen = pop.last_seen.at[jnp.asarray(sel)].set(round_idx)
        return sel

    # ----- list-selector-compatible adapter (small-N reference contract) ---

    def select(self, clients: Dict[int, ClientInfo], k: int, *,
               mem_required: float, stage_time_fn) -> List[int]:
        # candidate order mirrors the list path's two regimes: with fitted
        # communities the bandit sees sorted pool members, without them it
        # sees the clients dict in insertion order (tie-breaks and the
        # k >= #eligible early return follow that order)
        ids = sorted(clients) if self._communities else list(clients)
        infos = [clients[c] for c in ids]
        n_comm = 1
        community_id = None
        if self._communities:
            n_comm = len(self._communities)
            by_id = {cid: c for c, comm in enumerate(self._communities)
                     for cid in comm}
            community_id = [by_id.get(cid, n_comm) for cid in ids]
        pop = ClientPopulation.from_infos(
            infos, community_id=community_id, n_communities=n_comm)
        stage_time = jnp.asarray([stage_time_fn(c) for c in infos],
                                 jnp.float32)
        sel = self.select_arrays(pop, k, mem_required=mem_required,
                                 stage_time=stage_time,
                                 round_robin=self._communities is not None)
        return [ids[i] for i in sel]


def population_from_selector(selector: ParticipantSelector,
                             infos: Dict[int, ClientInfo]) -> ClientPopulation:
    """Convenience: snapshot a list-selector's world into arrays (communities
    included) — used by tests and the selector_scale benchmark."""
    comms = selector._communities or [sorted(infos)]
    ids = sorted(infos)
    by_id = {cid: c for c, comm in enumerate(comms) for cid in comm}
    community_id = [by_id.get(cid, len(comms)) for cid in ids]
    return ClientPopulation.from_infos(
        infos, community_id=community_id, n_communities=len(comms))
