"""Per-stage participant selection (paper §IV-C, Eqs. 11-14).

Pipeline per stage t:
  1. hard memory filter:   M(i, t) >= M_train(Theta_t)            (Eq. 12)
  2. feasibility check:    #eligible >= phi                        (Eq. 14)
  3. diversity:            cover RL-CD communities round-robin     (max Div)
  4. within community:     epsilon-greedy bandit on
                           Util_i = I_{t,i} - lambda * t_t^i       (Eq. 11)

This decouples the compound objective exactly as the paper does: community
coverage maximizes Div(S, t); the bandit maximizes sum Util.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.selector.bandit import UtilBandit, mix_seed
from repro.core.selector.rlcd import rlcd_communities


@dataclass
class ClientInfo:
    client_id: int
    memory_bytes: float          # device memory capacity
    capability: float            # runtime training capability c_i (FLOP/s)
    num_samples: int             # |D_i|
    loss_sum: float = 0.0        # I_{t,i}: summed local loss (Eq. 9)


class InfeasibleStageError(RuntimeError):
    """Eq. 14 violated: too few clients can fit the stage sub-model."""


@dataclass
class ParticipantSelector:
    lam: float = 1e-3            # lambda in Eq. 11
    epsilon: float = 0.2
    phi: int = 2                 # Eq. 14 minimum eligible clients
    seed: int = 0
    _bandit: UtilBandit = field(default=None)
    _communities: Optional[List[List[int]]] = None

    def __post_init__(self):
        if self._bandit is None:
            self._bandit = UtilBandit(epsilon=self.epsilon, seed=self.seed)

    # ----- setup -----

    def fit_communities(self, similarity: np.ndarray):
        self._communities = rlcd_communities(similarity, seed=self.seed)
        return self._communities

    # ----- per-round selection -----

    def eligible(self, clients: Dict[int, ClientInfo], mem_required: float
                 ) -> List[int]:
        return [cid for cid, c in clients.items() if c.memory_bytes >= mem_required]

    def utilities(self, clients: Dict[int, ClientInfo], stage_time_fn) -> Dict[int, float]:
        """Util_i = I_{t,i} - lambda * t_t^i (Eq. 11 per-client term)."""
        return {cid: c.loss_sum - self.lam * stage_time_fn(c)
                for cid, c in clients.items()}

    def select(self, clients: Dict[int, ClientInfo], k: int, *,
               mem_required: float, stage_time_fn) -> List[int]:
        elig = self.eligible(clients, mem_required)
        if len(elig) < self.phi:
            raise InfeasibleStageError(
                f"only {len(elig)} clients fit {mem_required / 2**20:.0f} MiB "
                f"(phi={self.phi}) — repartition blocks or lower batch size")
        utils = self.utilities({c: clients[c] for c in elig}, stage_time_fn)
        for cid, u in utils.items():
            self._bandit.update(cid, u)
        self._bandit.next_round()

        if not self._communities:
            return self._bandit.pick(elig, min(k, len(elig)))

        # round-robin across communities (maximize Div), bandit within
        chosen: List[int] = []
        pools = [[c for c in comm if c in set(elig)] for comm in self._communities]
        pools = [p for p in pools if p]
        rng = np.random.RandomState(mix_seed(self.seed, self._bandit._round))
        order = rng.permutation(len(pools))
        ci = 0
        while len(chosen) < min(k, len(elig)) and pools:
            pool = pools[order[ci % len(pools)] % len(pools)]
            remaining = [c for c in pool if c not in chosen]
            if remaining:
                pick = self._bandit.pick(remaining, 1)
                chosen.extend(pick)
            else:
                pools = [p for p in pools if any(c not in chosen for c in p)]
                order = rng.permutation(len(pools)) if pools else order
            ci += 1
        return chosen

    def data_diversity(self, selected: Sequence[int], similarity: np.ndarray) -> float:
        """Div(S, t) = 1 / sum_{i,j in S} Omega_ij (paper §IV-C3)."""
        idx = np.asarray(list(selected))
        if idx.size < 2:
            return float("inf")
        total = similarity[np.ix_(idx, idx)].sum() - np.trace(similarity[np.ix_(idx, idx)])
        return 1.0 / max(total, 1e-9)
