from repro.core.selector.similarity import (label_sketches, output_layer_gradient,
                                            similarity_matrix, sketch_projection,
                                            topm_neighbors)
from repro.core.selector.louvain import louvain
from repro.core.selector.rlcd import (label_propagation, rlcd_communities,
                                      sketch_communities)
from repro.core.selector.bandit import UtilBandit, mix_seed
from repro.core.selector.selection import ParticipantSelector, ClientInfo
from repro.core.selector.vectorized import (ClientPopulation, VectorizedSelector,
                                            population_from_selector)
