from repro.core.selector.similarity import output_layer_gradient, similarity_matrix
from repro.core.selector.louvain import louvain
from repro.core.selector.rlcd import rlcd_communities
from repro.core.selector.bandit import UtilBandit
from repro.core.selector.selection import ParticipantSelector, ClientInfo
