"""RL-CD: Robust Louvain community detection (paper §IV-C5).

Louvain alone groups by coarse label overlap; RL-CD recursively re-partitions
any community whose internal similarity-weight distribution still shows a
clear hierarchy (Standard_stop), after *sharpening* the weights at the median
(paper Step 3: weights below the median are zeroed, above are kept) so the
next Louvain pass separates the sub-structure.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.selector.louvain import louvain


def _has_weight_hierarchy(w: np.ndarray, *, gap_factor: float = 1.2,
                          min_edges: int = 3) -> bool:
    """Standard_stop check: does the weight distribution split into clearly
    separated low/high groups? 2-means separation vs within-spread test."""
    w = w[w > 0]
    if w.size < min_edges:
        return False
    lo, hi = w.min(), w.max()
    if hi - lo < 1e-9:
        return False
    # 2-means on 1-D weights
    c0, c1 = lo, hi
    for _ in range(20):
        assign = np.abs(w - c0) <= np.abs(w - c1)
        if assign.all() or (~assign).all():
            return False
        n0, n1 = w[assign], w[~assign]
        c0n, c1n = n0.mean(), n1.mean()
        if abs(c0n - c0) + abs(c1n - c1) < 1e-12:
            break
        c0, c1 = c0n, c1n
    spread = max(n0.std(), n1.std(), 1e-9)
    return abs(c1 - c0) > gap_factor * spread


def _sharpen(W: np.ndarray) -> np.ndarray:
    """Median-threshold sharpening (paper Step 3)."""
    vals = W[np.triu_indices_from(W, k=1)]
    vals = vals[vals > 0]
    if vals.size == 0:
        return W
    med = np.median(vals)
    Ws = W.copy()
    Ws[Ws < med] = 0.0
    return Ws


def rlcd_communities(W: np.ndarray, *, max_depth: int = 4,
                     min_size: int = 2, seed: int = 0) -> List[List[int]]:
    """Full RL-CD: iterative Louvain + sharpening until Standard_stop holds
    in every community. Returns communities of original indices."""
    W = np.asarray(W, np.float64)
    n = W.shape[0]
    Wp = np.maximum(W.copy(), 0.0)
    np.fill_diagonal(Wp, 0.0)

    final: List[List[int]] = []
    stack = [(list(range(n)), 0)]
    while stack:
        nodes, depth = stack.pop()
        if len(nodes) <= min_size or depth >= max_depth:
            final.append(sorted(nodes))
            continue
        sub = Wp[np.ix_(nodes, nodes)]
        w_flat = sub[np.triu_indices_from(sub, k=1)]
        if depth > 0 and not _has_weight_hierarchy(w_flat):
            final.append(sorted(nodes))  # Standard_stop met
            continue
        use = _sharpen(sub) if depth > 0 else sub
        comms = louvain(use, seed=seed + depth)
        if len(comms) <= 1:
            if depth == 0:
                final.append(sorted(nodes))
                continue
            # sharpened graph didn't split: stop here
            final.append(sorted(nodes))
            continue
        for c in comms:
            stack.append(([nodes[i] for i in c], depth + 1))
    return sorted(final, key=lambda c: c[0])
