"""RL-CD: Robust Louvain community detection (paper §IV-C5).

Louvain alone groups by coarse label overlap; RL-CD recursively re-partitions
any community whose internal similarity-weight distribution still shows a
clear hierarchy (Standard_stop), after *sharpening* the weights at the median
(paper Step 3: weights below the median are zeroed, above are kept) so the
next Louvain pass separates the sub-structure.
"""
from __future__ import annotations

from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selector.louvain import louvain


def _has_weight_hierarchy(w: np.ndarray, *, gap_factor: float = 1.2,
                          min_edges: int = 3) -> bool:
    """Standard_stop check: does the weight distribution split into clearly
    separated low/high groups? 2-means separation vs within-spread test."""
    w = w[w > 0]
    if w.size < min_edges:
        return False
    lo, hi = w.min(), w.max()
    if hi - lo < 1e-9:
        return False
    # 2-means on 1-D weights
    c0, c1 = lo, hi
    for _ in range(20):
        assign = np.abs(w - c0) <= np.abs(w - c1)
        if assign.all() or (~assign).all():
            return False
        n0, n1 = w[assign], w[~assign]
        c0n, c1n = n0.mean(), n1.mean()
        if abs(c0n - c0) + abs(c1n - c1) < 1e-12:
            break
        c0, c1 = c0n, c1n
    spread = max(n0.std(), n1.std(), 1e-9)
    return abs(c1 - c0) > gap_factor * spread


def _sharpen(W: np.ndarray) -> np.ndarray:
    """Median-threshold sharpening (paper Step 3)."""
    vals = W[np.triu_indices_from(W, k=1)]
    vals = vals[vals > 0]
    if vals.size == 0:
        return W
    med = np.median(vals)
    Ws = W.copy()
    Ws[Ws < med] = 0.0
    return Ws


def rlcd_communities(W: np.ndarray, *, max_depth: int = 4,
                     min_size: int = 2, seed: int = 0) -> List[List[int]]:
    """Full RL-CD: iterative Louvain + sharpening until Standard_stop holds
    in every community. Returns communities of original indices."""
    W = np.asarray(W, np.float64)
    n = W.shape[0]
    Wp = np.maximum(W.copy(), 0.0)
    np.fill_diagonal(Wp, 0.0)

    final: List[List[int]] = []
    stack = [(list(range(n)), 0)]
    while stack:
        nodes, depth = stack.pop()
        if len(nodes) <= min_size or depth >= max_depth:
            final.append(sorted(nodes))
            continue
        sub = Wp[np.ix_(nodes, nodes)]
        w_flat = sub[np.triu_indices_from(sub, k=1)]
        if depth > 0 and not _has_weight_hierarchy(w_flat):
            final.append(sorted(nodes))  # Standard_stop met
            continue
        use = _sharpen(sub) if depth > 0 else sub
        comms = louvain(use, seed=seed + depth)
        if len(comms) <= 1:
            if depth == 0:
                final.append(sorted(nodes))
                continue
            # sharpened graph didn't split: stop here
            final.append(sorted(nodes))
            continue
        for c in comms:
            stack.append(([nodes[i] for i in c], depth + 1))
    return sorted(final, key=lambda c: c[0])


# ---------------------------------------------------------------------------
# Population-scale path: vectorized label propagation over sketch-similarity
# neighbor lists. Louvain/RL-CD above stay the dense small-N oracle (tests
# cross-check the partitions on planted graphs).
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_iter",))
def _lpa_kernel(neighbors, weights, tol, *, n_iter):
    n, m = neighbors.shape
    self_lab = jnp.arange(n, dtype=jnp.int32)
    w_all = jnp.concatenate(
        [jnp.full((n, 1), 1e-6, jnp.float32),          # keep own label when
         jnp.maximum(weights, 0.0)], axis=1)           # no neighbor votes

    def step(labels, _):
        lab_all = jnp.concatenate([labels[:, None], labels[neighbors]], axis=1)
        # weighted vote per candidate label: pairwise-equality contraction
        # over the m+1 candidates (O(N * m^2), no N x L vote matrix)
        eq = lab_all[:, :, None] == lab_all[:, None, :]
        votes = jnp.sum(eq * w_all[:, None, :], axis=2)
        best = jnp.max(votes, axis=1, keepdims=True)
        # relaxed argmax: votes within (1 - tol) of the max count as tied,
        # ties resolve to the SMALLEST label. Synchronous max-vote LPA
        # oscillates / fragments when votes are near-equal (the arbitrary
        # winner freezes sub-splits); letting min-labels percolate through
        # near-ties makes tightly-knit groups converge to one label.
        new = jnp.min(jnp.where(votes >= best * (1.0 - tol), lab_all,
                                jnp.int32(n)), axis=1)
        return new, None

    labels, _ = jax.lax.scan(step, self_lab, None, length=n_iter)
    return labels


def label_propagation(neighbors, weights, *, n_iter: int = 30,
                      tol: float = 0.05) -> np.ndarray:
    """Vectorized weighted label propagation on a top-m neighbor graph.

    ``neighbors``/``weights`` are the [N, m] arrays from
    ``similarity.topm_neighbors``. Each sweep every node adopts the label
    with the largest (non-negative) weighted vote among itself and its m
    neighbors — the whole sweep is one [N, m+1, m+1] masked contraction, so
    a full pass over 100k clients is a few ms. Votes within ``tol``
    (relative) of the maximum count as tied and resolve to the smallest
    label, so the fixed ``n_iter``-sweep result is deterministic and
    near-uniform groups coalesce instead of oscillating.

    Returns dense labels renumbered to 0..K-1 (host side).
    """
    labels = np.asarray(_lpa_kernel(jnp.asarray(neighbors, jnp.int32),
                                    jnp.asarray(weights, jnp.float32),
                                    jnp.float32(tol), n_iter=n_iter))
    _, dense = np.unique(labels, return_inverse=True)
    return dense.astype(np.int32)


def _merge_by_centroid(labels: np.ndarray, sketches, *,
                       merge_threshold: float) -> np.ndarray:
    """Louvain-style aggregation level for LPA output: synchronous label
    propagation on a sparse kNN graph provably stalls at domain boundaries
    (a node with one minority-label neighbor can never flip), leaving pure
    but fragmented communities. Contract each community to its sketch
    centroid (segment_sum on device), then union communities whose centroid
    cosine clears ``merge_threshold`` — a C x C problem with C << N."""
    sk = np.asarray(sketches, np.float64)
    sk /= np.maximum(np.linalg.norm(sk, axis=1, keepdims=True), 1e-12)
    c = int(labels.max()) + 1
    cent = np.zeros((c, sk.shape[1]))
    np.add.at(cent, labels, sk)
    cent /= np.maximum(np.linalg.norm(cent, axis=1, keepdims=True), 1e-12)
    adj = cent @ cent.T >= merge_threshold
    # union-find over the (tiny) community graph
    parent = np.arange(c)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i, j in zip(*np.nonzero(np.triu(adj, 1))):
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[max(ri, rj)] = min(ri, rj)
    roots = np.asarray([find(i) for i in range(c)])
    _, dense = np.unique(roots, return_inverse=True)
    return dense[labels].astype(np.int32)


def sketch_communities(label_histograms: np.ndarray, *, sketch_dim: int = 64,
                       num_neighbors: int = 8, n_iter: int = 30,
                       seed: int = 0, block_rows: int = 4096,
                       merge_threshold: float = 0.9
                       ) -> Tuple[np.ndarray, int]:
    """End-to-end population-scale community detection: hashed
    label-distribution sketches -> tiled top-m cosine neighbors ->
    vectorized label propagation -> centroid merge. O(N^2 / block) flops but
    O(N * m) memory; never materializes the dense similarity matrix RL-CD
    needs.

    Returns (community_id [N], n_communities).
    """
    from repro.core.selector.similarity import (label_sketches,
                                                sketch_projection,
                                                topm_neighbors)

    hist = np.asarray(label_histograms, np.float32)
    proj = sketch_projection(hist.shape[1], sketch_dim, seed)
    sketches = label_sketches(hist, proj)
    nb, w = topm_neighbors(sketches, num_neighbors, block_rows=block_rows)
    labels = label_propagation(nb, w, n_iter=n_iter)
    if labels.max() > 0:
        labels = _merge_by_centroid(labels, sketches,
                                    merge_threshold=merge_threshold)
    return labels, (int(labels.max()) + 1 if len(labels) else 0)
