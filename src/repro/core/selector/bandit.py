"""epsilon-greedy multi-armed bandit over client utility (paper §IV-C6).

Util_i = I_{t,i} - lambda * t_t^i  (data importance minus weighted time).
Clients not selected recently have stale Util, so the bandit explores a
fraction epsilon of slots among under-observed clients (Oort-style)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

import numpy as np


def mix_seed(seed: int, round_idx: int) -> int:
    """Decorrelated per-round RNG seed. The old ``seed + round`` scheme made
    (seed=0, round=1) and (seed=1, round=0) share a stream, so two selectors
    with different seeds walked each other's exploration schedules one round
    apart. Multiplying the seed onto a large odd constant separates the
    streams; shared by UtilBandit, ParticipantSelector, and the vectorized
    selector so the list and array paths stay pick-identical."""
    return (seed * 1_000_003 + round_idx) % (2 ** 32)


@dataclass
class UtilBandit:
    epsilon: float = 0.2
    seed: int = 0
    _util: Dict[int, float] = field(default_factory=dict)
    _last_seen: Dict[int, int] = field(default_factory=dict)
    _round: int = 0

    def update(self, client_id: int, util: float):
        self._util[client_id] = float(util)
        self._last_seen[client_id] = self._round

    def next_round(self):
        self._round += 1

    def pick(self, candidates: Sequence[int], k: int) -> List[int]:
        """Pick k clients: (1-eps) exploit by Util, eps explore stalest."""
        rng = np.random.RandomState(mix_seed(self.seed, self._round))
        cands = list(candidates)
        if len(cands) <= k:
            return cands
        n_explore = int(round(self.epsilon * k))
        n_exploit = k - n_explore
        by_util = sorted(cands, key=lambda c: self._util.get(c, -np.inf),
                         reverse=True)
        exploit = by_util[:n_exploit]
        rest = [c for c in cands if c not in exploit]
        # explore the least recently observed (never-seen first)
        rest.sort(key=lambda c: (self._last_seen.get(c, -1), rng.rand()))
        explore = rest[:n_explore]
        return exploit + explore
