"""Stage-based memory model — the paper's Eq. (4), re-derived for TPU HBM.

    M_T = 2*(A(theta_T) + A(theta_op)) + P(Theta_T) + M_optimizer,T
          + max_layer_activation

where A(.) is activation bytes at the stage's batch/seq, P(.) the resident
parameter bytes (the frozen prefix is still needed for forward), and the
optimizer term covers ONLY the active block + output module (frozen blocks
carry no optimizer state — that is the paper's core memory saving).

Parameter counts come from ``jax.eval_shape`` over the real init (exact, no
allocation); activation estimates are structural per layer kind. The model is
validated against ``compiled.memory_analysis()`` in tests/test_memory_model.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import numpy as np

BYTES = {"bfloat16": 2, "float32": 4}

# Feature-cache precision tiers, in ADMISSION ORDER: servers try the most
# exact tier first and degrade (f32 -> fp16 -> int8) until a client's
# memory covers the stage requirement plus its shard's cache, declining the
# cache only when even int8 does not fit (fl/quant.py implements the
# encode/decode; fl/engine.py stores, fl/server.py admits).
CACHE_TIERS = ("f32", "fp16", "int8")
CACHE_TIER_DTYPES = {"f32": "float32", "fp16": "float16", "int8": "int8"}
_CACHE_DTYPE_BYTES = {"float32": 4.0, "bfloat16": 2.0, "float16": 2.0,
                      "int8": 1.0}


def cache_tier_ladder(memory_bytes: float, requirement_fn,
                      tiers=CACHE_TIERS) -> Optional[str]:
    """First tier in ``tiers`` whose total stage-plus-cache requirement
    (``requirement_fn(tier) -> bytes``) fits ``memory_bytes``; ``None``
    declines the cache (the client falls back to recomputing the frozen
    prefix every minibatch)."""
    for tier in tiers:
        if memory_bytes >= requirement_fn(tier):
            return tier
    return None


# ---------------------------------------------------------------------------
# Parameter counts (exact, via eval_shape)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _abstract_counts(cfg) -> Dict[str, int]:
    """Param counts by top-level group + per segment, from abstract init."""
    from repro.models.module import tree_paths
    from repro.models.transformer import build

    model = build(cfg)
    aparams = model.abstract_params()
    counts: Dict[str, int] = {}
    for path, leaf in tree_paths(aparams):
        key = path[0] if path[0] != "segments" else f"segments/{path[1]}"
        counts[key] = counts.get(key, 0) + int(np.prod(leaf.shape))
    return counts


def arch_param_count(cfg) -> int:
    return sum(_abstract_counts(cfg).values())


def arch_active_param_count(cfg) -> int:
    """Params touched per token (MoE: only top-k + shared experts active)."""
    total = arch_param_count(cfg)
    if not cfg.is_moe:
        return total
    n_moe = sum(1 for k in cfg.layer_kinds() if k == "attn_moe")
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = n_moe * (cfg.num_experts - cfg.experts_per_token) * per_expert
    return total - inactive


def block_param_counts(cfg) -> list:
    """Param count of each SmartFreeze block (layer-range partition)."""
    per_layer = _layer_param_counts(cfg)
    bounds = cfg.block_boundaries()
    return [int(sum(per_layer[lo:hi])) for lo, hi in zip(bounds[:-1], bounds[1:])]


def _layer_param_counts(cfg) -> list:
    counts = _abstract_counts(cfg)
    kinds = cfg.layer_kinds()
    segs = cfg.segments()
    out = []
    shared_total = counts.get("shared_attn", 0)
    n_shared = sum(1 for k in kinds if k == "shared_attn")
    li = 0
    for i, (kind, n) in enumerate(segs):
        if kind == "shared_attn":
            # amortize tied weights over occurrences
            out.extend([shared_total / max(n_shared, 1)] * n)
        else:
            seg_count = counts[f"segments/{i}"]
            out.extend([seg_count / n] * n)
        li += n
    return out


# ---------------------------------------------------------------------------
# Activation bytes (structural estimate per layer kind)
# ---------------------------------------------------------------------------


def layer_activation_bytes(cfg, batch: int, seq: int, kind: str) -> int:
    """Bytes of saved-for-backward intermediates for ONE layer (flash-style
    attention assumed: no S^2 score tensors; chunked scan for ssm kinds)."""
    b = BYTES[cfg.compute_dtype]
    d = cfg.d_model
    tok = batch * seq
    if kind in ("attn_mlp", "attn_moe", "shared_attn"):
        qkv = tok * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
        if cfg.attention == "mla":
            qkv = tok * (cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                         + cfg.kv_lora_rank + cfg.qk_rope_dim
                         + cfg.num_heads * cfg.v_head_dim)
        attn_out = tok * d
        if kind == "attn_moe":
            ff = tok * cfg.experts_per_token * cfg.moe_d_ff * 2
            ff += tok * cfg.num_shared_experts * cfg.moe_d_ff * 2
        else:
            ff = tok * cfg.d_ff * 2  # gate+up (down output is the residual)
        resid = 2 * tok * d  # ln1/ln2 inputs
        return (qkv + attn_out + ff + resid) * b
    if kind in ("mamba2", "mlstm"):
        di = cfg.ssm_expand * d
        proj = tok * 2 * di  # in_proj halves
        states = tok * (di + 2 * cfg.ssm_state)  # conv output
        return (proj + states + tok * d) * b
    if kind == "slstm":
        return (tok * 4 * d + tok * d) * b
    raise ValueError(kind)


def feature_cache_bytes(cfg, num_tokens: int, dtype: Optional[str] = None, *,
                        scale_vectors: int = 0) -> float:
    """Bytes to hold cached frozen-prefix activations for ``num_tokens``
    tokens of a client shard (the [*, d_model] hidden at the stage's
    stop-gradient boundary).

    ``dtype`` is the cache storage dtype — ``None`` keeps the legacy
    behavior (the config's compute dtype); ``"float16"``/``"int8"`` are the
    fp16/int8 tiers (fl/quant.py). An int8 cache additionally stores one
    f32 scale vector of ``d_model`` entries per quantization group
    (per-sample, per-channel) — pass the group count as ``scale_vectors``
    (``stage_memory_bytes`` derives it as ``cache_tokens // seq``).
    """
    per = (_CACHE_DTYPE_BYTES[dtype] if dtype is not None
           else BYTES[cfg.compute_dtype])
    total = float(num_tokens) * cfg.d_model * per
    if dtype == "int8":
        total += float(scale_vectors) * cfg.d_model * 4.0
    return total


def stage_memory_bytes(cfg, stage: int, batch: int, seq: int, *,
                       optimizer: str = "adamw",
                       op_module_layers: Optional[int] = None,
                       cache_tokens: int = 0,
                       cache_dtype: Optional[str] = None) -> Dict[str, float]:
    """Eq. (4) for SmartFreeze stage ``stage`` (0-based). Returns the terms.

    Vanilla full-model training is ``stage=None``-like via stage=T-1 plus
    counting all blocks active — use ``full_model_memory_bytes`` for that.

    ``cache_tokens``: frozen-prefix feature-cache hook (fl/engine.py). When a
    client additionally holds its shard's prefix activations, the requirement
    grows by ``feature_cache_bytes`` — the selector uses this to decline the
    cache on memory-poor clients. ``cache_dtype`` selects the cache storage
    tier (``"float32"``/``"float16"``/``"int8"``; ``None`` = compute dtype):
    the admission ladder calls this per tier and grants the first that fits,
    so an int8 cache (~4x smaller, incl. its per-sample scale vectors)
    admits clients the f32 cache would decline.
    """
    bounds = cfg.block_boundaries()
    lo, hi = bounds[stage], bounds[stage + 1]
    kinds = cfg.layer_kinds()
    pb = BYTES[cfg.param_dtype]
    per_layer_params = _layer_param_counts(cfg)
    T = cfg.num_freeze_blocks

    # P(Theta_T): all resident params (frozen prefix + active block + op)
    counts = _abstract_counts(cfg)
    embed_head = counts.get("embed", 0) + counts.get("head", 0) \
        + counts.get("frontend", 0) + counts.get("final_norm", 0)
    resident_layers = sum(per_layer_params[:hi])
    n_op = op_module_layers if op_module_layers is not None else (T - stage - 1)
    op_params = n_op * _proxy_layer_params(cfg) + cfg.d_model * cfg.vocab_size
    params_bytes = (resident_layers + embed_head + op_params) * pb

    # A(theta_T) + A(theta_op): activations of ACTIVE block + op, x2 for grads
    act_active = sum(layer_activation_bytes(cfg, batch, seq, kinds[i])
                     for i in range(lo, hi))
    act_op = n_op * layer_activation_bytes(cfg, batch, seq, "attn_mlp")
    act_term = 2 * (act_active + act_op)

    # optimizer state: active block + op only (AdamW: m+v fp32 + fp32 master)
    opt_mult = {"adamw": 12, "sgd": 4, "sgdm": 8}[optimizer]
    active_params = sum(per_layer_params[lo:hi]) + op_params
    opt_bytes = active_params * opt_mult

    # transient: the largest single-layer activation in the forward
    max_layer = max(layer_activation_bytes(cfg, batch, seq, kinds[i])
                    for i in range(0, hi))
    cache_b = feature_cache_bytes(
        cfg, cache_tokens, cache_dtype,
        scale_vectors=cache_tokens // max(seq, 1)) if cache_tokens else 0.0
    return {"params": params_bytes, "activations": act_term,
            "optimizer": opt_bytes, "max_transient": max_layer,
            "feature_cache": cache_b,
            "total": params_bytes + act_term + opt_bytes + max_layer + cache_b}


def full_model_memory_bytes(cfg, batch: int, seq: int, *,
                            optimizer: str = "adamw") -> Dict[str, float]:
    """Vanilla FL baseline: every layer trained, all activations stored."""
    kinds = cfg.layer_kinds()
    pb = BYTES[cfg.param_dtype]
    total_params = arch_param_count(cfg)
    act = sum(layer_activation_bytes(cfg, batch, seq, k) for k in kinds)
    opt_mult = {"adamw": 12, "sgd": 4, "sgdm": 8}[optimizer]
    max_layer = max(layer_activation_bytes(cfg, batch, seq, k) for k in kinds)
    return {"params": total_params * pb, "activations": 2 * act,
            "optimizer": total_params * opt_mult, "max_transient": max_layer,
            "total": total_params * pb + 2 * act + total_params * opt_mult + max_layer}


def _proxy_layer_params(cfg) -> int:
    """Output-module proxy layer: attn + slim MLP (d_ff = d_model)."""
    d = cfg.d_model
    attn = d * cfg.num_heads * cfg.head_dim * 2 \
        + d * cfg.num_kv_heads * cfg.head_dim * 2
    return attn + 3 * d * d


# ---------------------------------------------------------------------------
# FLOPs (Eq. 5) — per-token forward FLOPs per layer, and stage totals
# ---------------------------------------------------------------------------


def layer_fwd_flops_per_token(cfg, kind: str, seq: int) -> float:
    d = cfg.d_model
    if kind in ("attn_mlp", "attn_moe", "shared_attn"):
        if cfg.attention == "mla":
            qk_d = cfg.qk_nope_dim + cfg.qk_rope_dim
            proj = 2 * d * (cfg.q_lora_rank or d) + 2 * cfg.q_lora_rank * cfg.num_heads * qk_d \
                if cfg.q_lora_rank else 2 * d * cfg.num_heads * qk_d
            proj += 2 * d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
            proj += 2 * cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
            proj += 2 * cfg.num_heads * cfg.v_head_dim * d
            attn_core = 2 * 2 * cfg.num_heads * qk_d * seq / 2  # causal avg
        else:
            proj = 2 * d * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim \
                + 2 * cfg.num_heads * cfg.head_dim * d
            attn_core = 2 * 2 * cfg.num_heads * cfg.head_dim * seq / 2
        if kind == "attn_moe":
            ff = 2 * 3 * d * cfg.moe_d_ff * (cfg.experts_per_token + cfg.num_shared_experts)
            ff += 2 * d * cfg.num_experts  # router
        else:
            ff = 2 * 3 * d * cfg.d_ff
        return proj + attn_core + ff
    if kind == "mamba2":
        di = cfg.ssm_expand * d
        H = di // cfg.ssm_head_dim
        proj = 2 * d * (2 * di + 2 * cfg.ssm_state + H) + 2 * di * d
        scan = 2 * 3 * di * cfg.ssm_state  # state update + readout
        return proj + scan
    if kind == "mlstm":
        di = cfg.ssm_expand * d
        proj = 2 * d * 2 * di + 2 * 3 * di * di + 2 * di * d
        hd = di // max(cfg.num_heads, 1)
        scan = 2 * 3 * di * hd  # matrix-memory update/readout per token
        return proj + scan
    if kind == "slstm":
        hd = d // max(cfg.num_heads, 1)
        return 2 * d * 4 * d + 2 * max(cfg.num_heads, 1) * hd * 4 * hd + 2 * 2 * d * int(d * 4 / 3)
    raise ValueError(kind)


def stage_flops(cfg, stage: int, batch: int, seq: int) -> Dict[str, float]:
    """Eq. (5): FLOPs_T = fwd(frozen prefix + active + op) + bwd(active + op)."""
    bounds = cfg.block_boundaries()
    lo, hi = bounds[stage], bounds[stage + 1]
    kinds = cfg.layer_kinds()
    tok = batch * seq
    T = cfg.num_freeze_blocks
    n_op = T - stage - 1
    fwd_frozen = sum(layer_fwd_flops_per_token(cfg, kinds[i], seq) for i in range(lo))
    fwd_active = sum(layer_fwd_flops_per_token(cfg, kinds[i], seq) for i in range(lo, hi))
    fwd_op = n_op * layer_fwd_flops_per_token(cfg, "attn_mlp", seq) * 0.5  # slim proxy
    head = 2 * cfg.d_model * cfg.vocab_size
    fwd = (fwd_frozen + fwd_active + fwd_op + head) * tok
    bwd = 2 * (fwd_active + fwd_op + head) * tok  # bwd ~ 2x fwd, active only
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}


def full_model_flops(cfg, batch: int, seq: int) -> float:
    kinds = cfg.layer_kinds()
    tok = batch * seq
    per_tok = sum(layer_fwd_flops_per_token(cfg, k, seq) for k in kinds)
    head = 2 * cfg.d_model * cfg.vocab_size
    return (per_tok + head) * tok * 3  # fwd + 2x bwd


def model_flops_6nd(cfg, batch: int, seq: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for §Roofline."""
    return 6.0 * arch_active_param_count(cfg) * batch * seq


# ---------------------------------------------------------------------------
# CNN testbed memory model (Eq. 4 for the vision servers; previously lived in
# fl/server.py — fl.server re-exports these names for backward compat)
# ---------------------------------------------------------------------------


def cnn_feature_cache_bytes(model, stage: int, num_samples: int,
                            image_size: int = 32,
                            dtype: str = "float32") -> float:
    """Bytes to hold a client shard's frozen-prefix activations: the
    feature map at the stage boundary, one per local sample, stored at the
    cache tier's ``dtype`` (``"float32"``/``"float16"``/``"int8"`` —
    fl/quant.py). An int8 cache adds one f32 scale per (sample, channel)
    quantization group."""
    if stage <= 0:
        return 0.0
    cfg = model.cfg
    ch = cfg.stage_channels[stage - 1]
    if cfg.kind == "vgg":  # maxpool halves after every stage
        res = max(image_size // (2 ** stage), 1)
    else:  # resnet: stride-2 at each stage entry except stage 0
        res = max(image_size // (2 ** (stage - 1)), 1)
    total = float(num_samples) * res * res * ch * _CACHE_DTYPE_BYTES[dtype]
    if dtype == "int8":
        total += float(num_samples) * ch * 4.0
    return total


def cnn_stage_memory_bytes(model, stage: int, batch_size: int,
                           image_size: int = 32, *,
                           cache_samples: int = 0,
                           cache_dtype: str = "float32") -> float:
    """Eq. (4) for the CNN testbed (fp32). ``cache_samples`` is the feature
    cache hook: when a client would additionally hold its shard's frozen-
    prefix activations, the requirement grows by ``cnn_feature_cache_bytes``
    — the selector/server uses this to decline the cache on memory-poor
    clients (who fall back to recomputing the prefix). ``cache_dtype``
    prices the cache at a storage tier (fl/quant.py): the admission ladder
    (``cache_tier_ladder``) evaluates this per tier f32 -> fp16 -> int8 and
    grants the first that fits."""
    cfg = model.cfg
    res = image_size
    act = 0.0
    max_act = 0.0
    params = 0.0
    for i, (nb, ch) in enumerate(zip(cfg.stage_sizes, cfg.stage_channels)):
        r = res // (2 ** i) if cfg.kind == "vgg" else max(res // (2 ** max(i, 0)), 4)
        a = batch_size * r * r * ch * 4.0 * nb * 2  # convs per stage
        max_act = max(max_act, a / max(nb, 1))
        c_in = cfg.stage_channels[max(i - 1, 0)]
        params += nb * (9 * c_in * ch + 9 * ch * ch) * 4.0
        if i == stage:
            act = a
        if i >= stage:
            break
    opt = params * 2.0  # momentum
    total = 2 * act + params + opt + max_act
    if cache_samples:
        total += cnn_feature_cache_bytes(model, stage, cache_samples,
                                         image_size, cache_dtype)
    return total
