"""Pace controller (paper §IV-B): data-free convergence detection per block.

Block perturbation over an update window Q (Eq. 2):

    P_t^{r,Q} = || sum_{q<Q} W_t^{r-q} || / sum_{q<Q} || W_t^{r-q} ||

The numerator telescopes: sum of the last Q updates == theta^r - theta^{r-Q},
so the exact sliding window needs only a FIFO of Q parameter snapshots of the
*active block* (1/T of the model, sharded like the params); the denominator is
a FIFO of scalar norms. A smoothing window H (Eq. 3) and a least-squares slope
test (|slope| < Lambda for mu consecutive rounds) gate the freeze.

The controller is control-plane: it consumes per-round scalar norms computed
on-mesh (kernels/block_perturb for the fused norm) and decides on host.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32), a, b)


def tree_norm(t) -> float:
    from repro.optim import global_norm

    return float(global_norm(t))


@dataclass
class PaceController:
    """One controller instance per SmartFreeze block (the active one)."""

    window_q: int = 5        # Eq. 2 update window
    smooth_h: int = 5        # Eq. 3 smoothing window
    slope_lambda: float = 2e-3   # freeze threshold on |slope|
    mu: int = 3              # consecutive rounds below threshold
    fit_window: int = 8      # points used for the least-squares fit
    min_rounds: int = 10     # never freeze before this many rounds

    _snapshots: Deque = field(default_factory=deque)  # theta^{r-q} FIFO
    _update_norms: Deque = field(default_factory=deque)
    _perturbations: List[float] = field(default_factory=list)
    _smoothed: List[float] = field(default_factory=list)
    _below: int = 0
    _rounds: int = 0

    # ----- per-round observation -----

    def observe(self, block_params) -> Optional[float]:
        """Call once per round with the aggregated active-block params.

        Returns the smoothed block perturbation (None until >= 2 rounds).
        """
        params = jax.tree.map(lambda x: np.asarray(x, np.float32), block_params)
        if self._snapshots:
            latest = self._snapshots[-1]
            upd_norm = _np_norm(_np_sub(params, latest))
            self._update_norms.append(upd_norm)
            if len(self._update_norms) > self.window_q:
                self._update_norms.popleft()
        self._snapshots.append(params)
        if len(self._snapshots) > self.window_q + 1:
            self._snapshots.popleft()
        self._rounds += 1
        if len(self._snapshots) < 2:
            return None
        # numerator: telescoped sum of the last <=Q updates
        num = _np_norm(_np_sub(self._snapshots[-1], self._snapshots[0]))
        den = sum(self._update_norms) + 1e-12
        p = num / den
        self._perturbations.append(p)
        h = min(self.smooth_h, len(self._perturbations))
        sm = float(np.mean(self._perturbations[-h:]))
        self._smoothed.append(sm)
        return sm

    # ----- freeze decision -----

    def slope(self) -> Optional[float]:
        n = min(self.fit_window, len(self._smoothed))
        if n < 3:
            return None
        y = np.asarray(self._smoothed[-n:], np.float64)
        x = np.arange(n, dtype=np.float64)
        return float(np.polyfit(x, y, 1)[0])

    def should_freeze(self) -> bool:
        if self._rounds < self.min_rounds:
            return False
        s = self.slope()
        if s is None:
            return False
        if abs(s) < self.slope_lambda:
            self._below += 1
        else:
            self._below = 0
        return self._below >= self.mu

    @property
    def history(self):
        return {"perturbation": list(self._perturbations),
                "smoothed": list(self._smoothed), "rounds": self._rounds}


def _np_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def _np_norm(t) -> float:
    total = 0.0
    for leaf in jax.tree.leaves(t):
        total += float(np.sum(np.square(leaf, dtype=np.float64)))
    return float(np.sqrt(total))


# ---------------------------------------------------------------------------
# Ablation schedules (paper Table II comparisons)
# ---------------------------------------------------------------------------


def naive_equal_schedule(total_rounds: int, num_blocks: int) -> List[int]:
    """(c) rounds allocated proportional to block index (param-count proxy)."""
    base = total_rounds // num_blocks
    return [base] * num_blocks


def front_loaded_schedule(total_rounds: int, num_blocks: int) -> List[int]:
    """(b) freeze early blocks prematurely; spend rounds on the last block."""
    early = max(total_rounds // (4 * num_blocks), 1)
    sched = [early] * (num_blocks - 1)
    sched.append(total_rounds - sum(sched))
    return sched
