"""Pace controller (paper §IV-B): data-free convergence detection per block.

Block perturbation over an update window Q (Eq. 2):

    P_t^{r,Q} = || sum_{q<Q} W_t^{r-q} || / sum_{q<Q} || W_t^{r-q} ||

The numerator telescopes: sum of the last Q updates == theta^r - theta^{r-Q},
so the window state is the ``theta^{r-Q}`` boundary snapshot, the running
parameters, and a FIFO of scalar update norms — the seed kept Q+1 structured
pytree snapshot copies; this version stores the window as flat fp32 vectors
(the exact sliding window provably needs the intermediate iterates too, since
each becomes a future boundary, but flattening drops the per-leaf tree
overhead and makes the whole window one checkpointable [W+1, n] array).
``low_memory=True`` switches to an anchored (hopping) window that keeps only
the boundary snapshot plus the previous iterate — two block copies total
instead of Q+1 — at the cost of the window re-anchoring every Q rounds
(perturbation series approximate, freeze decisions within a round or two on
converging sequences; property-tested).

A smoothing window H (Eq. 3) and a least-squares slope test
(|slope| < Lambda for mu consecutive rounds) gate the freeze.

The controller is control-plane: it consumes per-round scalar norms computed
on-mesh (kernels/block_perturb for the fused norm) and decides on host.
``state_dict()/load_state_dict()`` serialize the full window + decision
state as numpy arrays, so a checkpointed federated run resumes with a
bit-identical perturbation series (fl/sim.py).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32), a, b)


def tree_norm(t) -> float:
    from repro.optim import global_norm

    return float(global_norm(t))


def _flatten(block_params) -> np.ndarray:
    """One contiguous fp32 vector per observation (leaf order is the pytree
    iteration order, stable for a fixed block structure)."""
    leaves = [np.asarray(l, np.float32).ravel()
              for l in jax.tree.leaves(block_params)]
    if not leaves:
        return np.zeros(0, np.float32)
    return np.concatenate(leaves) if len(leaves) > 1 else leaves[0].copy()


def _norm(v: np.ndarray) -> float:
    return float(np.sqrt(np.sum(np.square(v, dtype=np.float64))))


@dataclass
class PaceController:
    """One controller instance per SmartFreeze block (the active one)."""

    window_q: int = 5        # Eq. 2 update window
    smooth_h: int = 5        # Eq. 3 smoothing window
    slope_lambda: float = 2e-3   # freeze threshold on |slope|
    mu: int = 3              # consecutive rounds below threshold
    fit_window: int = 8      # points used for the least-squares fit
    min_rounds: int = 10     # never freeze before this many rounds
    low_memory: bool = False  # anchored window: 2 block copies instead of Q+1

    _window: Deque = field(default_factory=deque)      # flat snapshots (exact)
    _anchor: Optional[np.ndarray] = None               # boundary (low_memory)
    _prev: Optional[np.ndarray] = None                 # theta^{r-1} (low_memory)
    _update_norms: Deque = field(default_factory=deque)
    _perturbations: List[float] = field(default_factory=list)
    _smoothed: List[float] = field(default_factory=list)
    _below: int = 0
    _rounds: int = 0
    _skipped: int = 0    # non-finite observations dropped (fault screening)

    # ----- per-round observation -----

    def observe(self, block_params) -> Optional[float]:
        """Call once per round with the aggregated active-block params.

        Returns the smoothed block perturbation (None until >= 2 rounds).

        Non-finite params are REJECTED, not ingested (ISSUE 7): one NaN
        snapshot would poison every update norm it touches for the next Q
        rounds and the smoothed series permanently — a corrupted round must
        never be what convinces the controller a block "converged". The
        observation is skipped (counted in ``_skipped``) and the previous
        smoothed value is returned.
        """
        flat = _flatten(block_params)
        if not bool(np.isfinite(flat).all()):
            self._skipped += 1
            return self._smoothed[-1] if self._smoothed else None
        if self.low_memory:
            return self._observe_anchored(flat)
        if self._window:
            self._update_norms.append(_norm(flat - self._window[-1]))
            if len(self._update_norms) > self.window_q:
                self._update_norms.popleft()
        self._window.append(flat)
        if len(self._window) > self.window_q + 1:
            self._window.popleft()
        self._rounds += 1
        if len(self._window) < 2:
            return None
        # numerator: telescoped sum of the last <=Q updates
        num = _norm(self._window[-1] - self._window[0])
        return self._emit(num, sum(self._update_norms))

    def _observe_anchored(self, flat: np.ndarray) -> Optional[float]:
        self._rounds += 1
        if self._prev is None:
            self._prev = flat
            self._anchor = flat
            return None
        if len(self._update_norms) >= self.window_q:
            # hop: restart the window one update back, so the perturbation
            # is defined every round (window length cycles 1..Q)
            self._anchor = self._prev
            self._update_norms.clear()
        self._update_norms.append(_norm(flat - self._prev))
        self._prev = flat
        num = _norm(flat - self._anchor)
        return self._emit(num, sum(self._update_norms))

    def _emit(self, num: float, den: float) -> float:
        p = num / (den + 1e-12)
        self._perturbations.append(p)
        h = min(self.smooth_h, len(self._perturbations))
        sm = float(np.mean(self._perturbations[-h:]))
        self._smoothed.append(sm)
        return sm

    # ----- freeze decision -----

    def slope(self) -> Optional[float]:
        n = min(self.fit_window, len(self._smoothed))
        if n < 3:
            return None
        y = np.asarray(self._smoothed[-n:], np.float64)
        x = np.arange(n, dtype=np.float64)
        return float(np.polyfit(x, y, 1)[0])

    def should_freeze(self) -> bool:
        if self._rounds < self.min_rounds:
            return False
        s = self.slope()
        if s is None:
            return False
        if abs(s) < self.slope_lambda:
            self._below += 1
        else:
            self._below = 0
        return self._below >= self.mu

    @property
    def history(self):
        return {"perturbation": list(self._perturbations),
                "smoothed": list(self._smoothed), "rounds": self._rounds,
                "skipped": self._skipped}

    # ----- checkpoint/resume (fl/sim.py) -----

    def state_dict(self) -> Dict[str, np.ndarray]:
        """Full controller state as numpy arrays (CheckpointManager-ready)."""
        n = self._window[-1].size if self._window else (
            self._prev.size if self._prev is not None else 0)
        out = {
            "window": (np.stack(self._window) if self._window
                       else np.zeros((0, n), np.float32)),
            "anchor": (self._anchor if self._anchor is not None
                       else np.zeros((0,), np.float32)),
            "prev": (self._prev if self._prev is not None
                     else np.zeros((0,), np.float32)),
            "update_norms": np.asarray(list(self._update_norms), np.float64),
            "perturbations": np.asarray(self._perturbations, np.float64),
            "smoothed": np.asarray(self._smoothed, np.float64),
            "counters": np.asarray([self._below, self._rounds,
                                    self._skipped], np.int64),
        }
        return out

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> "PaceController":
        w = np.asarray(state["window"], np.float32)
        self._window = deque(list(w))
        anchor = np.asarray(state["anchor"], np.float32)
        prev = np.asarray(state["prev"], np.float32)
        self._anchor = anchor if anchor.size else None
        self._prev = prev if prev.size else None
        self._update_norms = deque(
            float(x) for x in np.asarray(state["update_norms"]))
        self._perturbations = [float(x)
                               for x in np.asarray(state["perturbations"])]
        self._smoothed = [float(x) for x in np.asarray(state["smoothed"])]
        cs = [int(x) for x in np.asarray(state["counters"])]
        self._below, self._rounds = cs[0], cs[1]
        # pre-ISSUE-7 checkpoints carry a 2-entry counter vector
        self._skipped = cs[2] if len(cs) > 2 else 0
        return self


# ---------------------------------------------------------------------------
# Ablation schedules (paper Table II comparisons)
# ---------------------------------------------------------------------------


def naive_equal_schedule(total_rounds: int, num_blocks: int) -> List[int]:
    """(c) rounds allocated proportional to block index (param-count proxy)."""
    base = total_rounds // num_blocks
    return [base] * num_blocks


def front_loaded_schedule(total_rounds: int, num_blocks: int) -> List[int]:
    """(b) freeze early blocks prematurely; spend rounds on the last block."""
    early = max(total_rounds // (4 * num_blocks), 1)
    sched = [early] * (num_blocks - 1)
    sched.append(total_rounds - sum(sched))
    return sched
