"""Progressive stage training for the CNN repro models (paper testbed).

Faithful to §IV-A: the stage-t submodel is [stem?, stages 0..t, output
module]; suffix stages DO NOT EXIST yet (model growth). Frozen prefix runs in
eval mode (BN running stats) under stop_gradient; only stage t (+stem at t=0)
and the output module are differentiated/optimized.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import output_module as op_mod
from repro.models.cnn import CNN, softmax_xent
from repro.models.module import PFac, Params
from repro.optim import Optimizer, apply_updates, clip_by_global_norm


def split_cnn_params(model: CNN, params: Params, stage: int
                     ) -> Tuple[Params, Params]:
    n_stages = len(model.cfg.stage_sizes)
    frozen: Params = {"stages": {}}
    active: Params = {"stages": {}}
    if model.cfg.kind == "resnet":
        (active if stage == 0 else frozen)["stem"] = params["stem"]
    for i in range(stage):
        frozen["stages"][f"stage{i}"] = params["stages"][f"stage{i}"]
    active["stages"][f"stage{stage}"] = params["stages"][f"stage{stage}"]
    if stage == n_stages - 1:
        active["fc"] = params["fc"]
    return frozen, active


def merge_cnn_params(model: CNN, params: Params, stage: int, active: Params) -> Params:
    new = {k: v for k, v in params.items()}
    new["stages"] = dict(params["stages"])
    if "stem" in active:
        new["stem"] = active["stem"]
    new["stages"][f"stage{stage}"] = active["stages"][f"stage{stage}"]
    if "fc" in active:
        new["fc"] = active["fc"]
    return new


def init_cnn_stage_active(model: CNN, params: Params, stage: int, rng, *,
                          op_kind: str = "conv") -> Tuple[Params, Params]:
    """op_kind: conv (paper) | fc_only (ablation) | none (final stage)."""
    frozen, active = split_cnn_params(model, params, stage)
    n_stages = len(model.cfg.stage_sizes)
    if stage < n_stages - 1:
        fac = PFac(rng, dtype=jnp.float32)
        if op_kind == "conv":
            active["op"] = op_mod.cnn_op_init(fac.sub("op"), model.cfg, stage)
        elif op_kind == "fc_only":
            active["op"] = op_mod.cnn_fc_only_init(fac.sub("op"), model.cfg, stage)
    return frozen, active


def cnn_prefix_features(model: CNN, frozen: Params, bn_state: Params,
                        x: jnp.ndarray, stage: int) -> jnp.ndarray:
    """Forward of the frozen prefix only (stem + stages [0, stage)), eval
    mode, stop-gradient boundary. Within a stage the prefix params AND its
    BN running stats are fixed, so this is a pure function of ``x`` — the
    round engine computes it once per (client, stage) and caches the result
    as a fixed feature extractor (NeuLite/ProFL-style). Stage 0 has no
    frozen prefix: the identity is returned."""
    if stage == 0:
        return x
    h = x
    if model.cfg.kind == "resnet":
        h, _ = model.stem(frozen, bn_state, h, train=False)
    h, _ = model.run_stages(frozen, bn_state, h, 0, stage, train=False)
    return jax.lax.stop_gradient(h)


def cnn_stage_forward_from_features(model: CNN, active: Params,
                                    bn_state: Params, h: jnp.ndarray,
                                    stage: int, *, op_kind: str = "conv",
                                    train: bool = True):
    """Active-suffix forward: consumes frozen-prefix features (or raw images
    at stage 0) and runs active stage (+stem at stage 0) and the head/output
    module. ``cnn_stage_forward`` composes prefix+suffix, so cached-feature
    training is numerically identical to full recompute by construction."""
    cfg = model.cfg
    n_stages = len(cfg.stage_sizes)
    if stage == 0 and cfg.kind == "resnet":
        h, bn_state = model.stem(active, bn_state, h, train=train)
    h, bn_state = model.run_stages(active, bn_state, h, stage, stage + 1,
                                   train=train)
    if stage == n_stages - 1:
        logits = model.head(active, h)
    elif op_kind == "fc_only":
        logits = op_mod.cnn_fc_only_apply(active["op"], h)
    else:
        logits = op_mod.cnn_op_apply(active["op"], h, cfg, stage)
    return logits, bn_state


def cnn_stage_forward(model: CNN, frozen: Params, active: Params,
                      bn_state: Params, x: jnp.ndarray, stage: int, *,
                      op_kind: str = "conv", train: bool = True):
    h = cnn_prefix_features(model, frozen, bn_state, x, stage)
    return cnn_stage_forward_from_features(model, active, bn_state, h, stage,
                                           op_kind=op_kind, train=train)


def cnn_stage_loss_fn(model: CNN, stage: int, *, op_kind: str = "conv"):
    def loss_fn(active, frozen, bn_state, batch):
        logits, new_state = cnn_stage_forward(model, frozen, active, bn_state,
                                              batch["x"], stage, op_kind=op_kind)
        return softmax_xent(logits, batch["y"]), new_state

    return loss_fn


def cnn_cached_stage_loss_fn(model: CNN, stage: int, *, op_kind: str = "conv"):
    """Stage loss over pre-extracted frozen-prefix features: ``batch["x"]``
    holds cached activations instead of images; the frozen tree is unused."""
    def loss_fn(active, frozen, bn_state, batch):
        logits, new_state = cnn_stage_forward_from_features(
            model, active, bn_state, batch["x"], stage, op_kind=op_kind)
        return softmax_xent(logits, batch["y"]), new_state

    return loss_fn


def make_cnn_stage_step(model: CNN, stage: int, optimizer: Optimizer, *,
                        op_kind: str = "conv", clip_norm: float = 10.0):
    loss_fn = cnn_stage_loss_fn(model, stage, op_kind=op_kind)

    def step(active, frozen, bn_state, opt_state, batch):
        (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            active, frozen, bn_state, batch)
        grads, _ = clip_by_global_norm(grads, clip_norm)
        ups, opt_state = optimizer.update(grads, opt_state, active)
        active = apply_updates(active, ups)
        return active, new_bn, opt_state, loss

    return jax.jit(step)
