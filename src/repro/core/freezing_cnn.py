"""Progressive stage training for the CNN repro models (paper testbed).

Faithful to §IV-A: the stage-t submodel is [stem?, stages 0..t, output
module]; suffix stages DO NOT EXIST yet (model growth). Frozen prefix runs in
eval mode (BN running stats) under stop_gradient; only stage t (+stem at t=0)
and the output module are differentiated/optimized.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import output_module as op_mod
from repro.models.cnn import CNN
from repro.models.module import PFac, Params
from repro.optim import Optimizer, apply_updates, clip_by_global_norm


def split_cnn_params(model: CNN, params: Params, stage: int
                     ) -> Tuple[Params, Params]:
    n_stages = len(model.cfg.stage_sizes)
    frozen: Params = {"stages": {}}
    active: Params = {"stages": {}}
    if model.cfg.kind == "resnet":
        (active if stage == 0 else frozen)["stem"] = params["stem"]
    for i in range(stage):
        frozen["stages"][f"stage{i}"] = params["stages"][f"stage{i}"]
    active["stages"][f"stage{stage}"] = params["stages"][f"stage{stage}"]
    if stage == n_stages - 1:
        active["fc"] = params["fc"]
    return frozen, active


def merge_cnn_params(model: CNN, params: Params, stage: int, active: Params) -> Params:
    new = {k: v for k, v in params.items()}
    new["stages"] = dict(params["stages"])
    if "stem" in active:
        new["stem"] = active["stem"]
    new["stages"][f"stage{stage}"] = active["stages"][f"stage{stage}"]
    if "fc" in active:
        new["fc"] = active["fc"]
    return new


def init_cnn_stage_active(model: CNN, params: Params, stage: int, rng, *,
                          op_kind: str = "conv") -> Tuple[Params, Params]:
    """op_kind: conv (paper) | fc_only (ablation) | none (final stage)."""
    frozen, active = split_cnn_params(model, params, stage)
    n_stages = len(model.cfg.stage_sizes)
    if stage < n_stages - 1:
        fac = PFac(rng, dtype=jnp.float32)
        if op_kind == "conv":
            active["op"] = op_mod.cnn_op_init(fac.sub("op"), model.cfg, stage)
        elif op_kind == "fc_only":
            active["op"] = op_mod.cnn_fc_only_init(fac.sub("op"), model.cfg, stage)
    return frozen, active


def cnn_stage_forward(model: CNN, frozen: Params, active: Params,
                      bn_state: Params, x: jnp.ndarray, stage: int, *,
                      op_kind: str = "conv", train: bool = True):
    cfg = model.cfg
    n_stages = len(cfg.stage_sizes)
    merged: Params = {}
    if "stem" in active:
        merged["stem"] = active["stem"]
    elif "stem" in frozen:
        merged["stem"] = frozen["stem"]
    merged["stages"] = {**frozen["stages"], **active["stages"]}
    if "fc" in active:
        merged["fc"] = active["fc"]
    # stem
    if cfg.kind == "resnet":
        h, bn_state = model.stem(merged, bn_state, x, train=train and stage == 0)
    else:
        h = x
    # frozen prefix: eval mode, stop_gradient boundary
    if stage > 0:
        h, _ = model.run_stages(merged, bn_state, h, 0, stage, train=False)
        h = jax.lax.stop_gradient(h)
    # active stage
    h, bn_state = model.run_stages(merged, bn_state, h, stage, stage + 1,
                                   train=train)
    if stage == n_stages - 1:
        logits = model.head(merged, h)
    elif op_kind == "fc_only":
        logits = op_mod.cnn_fc_only_apply(active["op"], h)
    else:
        logits = op_mod.cnn_op_apply(active["op"], h, cfg, stage)
    return logits, bn_state


def cnn_stage_loss_fn(model: CNN, stage: int, *, op_kind: str = "conv"):
    def loss_fn(active, frozen, bn_state, batch):
        logits, new_state = cnn_stage_forward(model, frozen, active, bn_state,
                                              batch["x"], stage, op_kind=op_kind)
        lf = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, batch["y"][:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold), new_state

    return loss_fn


def make_cnn_stage_step(model: CNN, stage: int, optimizer: Optimizer, *,
                        op_kind: str = "conv", clip_norm: float = 10.0):
    loss_fn = cnn_stage_loss_fn(model, stage, op_kind=op_kind)

    def step(active, frozen, bn_state, opt_state, batch):
        (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            active, frozen, bn_state, batch)
        grads, _ = clip_by_global_norm(grads, clip_norm)
        ups, opt_state = optimizer.update(grads, opt_state, active)
        active = apply_updates(active, ups)
        return active, new_bn, opt_state, loss

    return jax.jit(step)
