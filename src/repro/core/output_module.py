"""Position-preserving output modules (paper §IV-A).

The block being trained must see a *stage-appropriate* downstream, or it
learns classifier features instead of its role in the full model. The paper
replaces each not-yet-trained block with one cheap position-preserving layer:

* CNNs (paper-exact): one stride-2 conv per remaining stage (channel-matched)
  + global pool + FC.
* LMs (our adaptation, DESIGN.md §2): one *slim proxy layer* per remaining
  block — same attention (sequence mixing preserves positional role) but a
  d_ff = d_model MLP — then final norm + a stage-local LM head. Measured
  overhead is reported by ``op_overhead`` (paper: 2.8% memory / 7.3% compute).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models.layers import conv2d, conv2d_init, dense, dense_init, norm, norm_init
from repro.models.module import PFac, Params, init_stack, axes_to_tree


def _proxy_cfg(cfg: ArchConfig) -> ArchConfig:
    """The slim proxy layer config: the arch's own attention geometry (so it
    shards identically), but a d_ff = d_model MLP — cheap position-preserving
    emulation of an untrained block (paper §IV-A adapted, DESIGN.md §2)."""
    return dataclasses.replace(
        cfg, d_ff=cfg.d_model, attention="gqa",
        num_experts=0, num_shared_experts=0, experts_per_token=0)


# ---------------------------------------------------------------------------
# LM output module
# ---------------------------------------------------------------------------


def lm_op_init(fac: PFac, cfg: ArchConfig, stage: int) -> Params:
    """Output module for stage t: (T-t-1) proxy layers + norm + head."""
    from repro.models.transformer import layer_init

    pcfg = _proxy_cfg(cfg)
    T = cfg.num_freeze_blocks
    n_proxy = max(T - stage - 1, 0)
    p: Params = {}
    if n_proxy:
        p["proxy"] = init_stack(fac.sub("proxy"), n_proxy,
                                lambda f: layer_init(f, pcfg, "attn_mlp"))
    p["norm"] = norm_init(fac, "norm", cfg.d_model, cfg.norm)
    p["head"] = dense_init(fac, "head", cfg.d_model, cfg.vocab_size,
                           ("embed", "vocab"))
    return p


def lm_op_hidden(p: Params, h: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Proxy layers + norm (head applied separately via chunked CE)."""
    from repro.models.transformer import layer_apply

    pcfg = _proxy_cfg(cfg)
    if "proxy" in p:
        def body(hh, lp):
            hh, _ = layer_apply(lp, hh, pcfg, "attn_mlp",
                                causal=not cfg.is_encoder_only)
            return hh, None

        h, _ = jax.lax.scan(body, h, p["proxy"])
    return norm(p["norm"], h, cfg.norm, cfg.norm_eps)


def lm_op_apply(p: Params, h: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    return dense(p["head"], lm_op_hidden(p, h, cfg))


def lm_op_abstract(cfg: ArchConfig, stage: int) -> Tuple[Params, Dict]:
    """(abstract params, axes tree) without allocation."""
    store: dict = {}

    def build():
        fac = PFac(jax.random.PRNGKey(0), dtype=jnp.bfloat16, axes_store=store)
        return lm_op_init(fac, cfg, stage)

    aparams = jax.eval_shape(build)
    return aparams, axes_to_tree(store)


# ---------------------------------------------------------------------------
# CNN output module (paper-exact conv emulation)
# ---------------------------------------------------------------------------


def cnn_op_init(fac: PFac, cnn_cfg, stage: int) -> Params:
    """One stride-2 conv per remaining stage, channel trajectory preserved."""
    chans = cnn_cfg.stage_channels
    n_stages = len(chans)
    p: Params = {"convs": {}}
    c_in = chans[stage]
    for i in range(stage + 1, n_stages):
        p["convs"][f"c{i}"] = conv2d_init(fac.sub("convs"), f"c{i}", c_in, chans[i], 3)
        c_in = chans[i]
    p["fc"] = {"w": fac.param("fc_w", (c_in, cnn_cfg.num_classes), (None, None),
                              init="normal"),
               "b": fac.param("fc_b", (cnn_cfg.num_classes,), (None,), init="zeros")}
    return p


def cnn_op_apply(p: Params, h: jnp.ndarray, cnn_cfg, stage: int) -> jnp.ndarray:
    n_stages = len(cnn_cfg.stage_channels)
    for i in range(stage + 1, n_stages):
        stride = 2 if (cnn_cfg.kind == "resnet" and i > 0) or cnn_cfg.kind == "vgg" else 1
        h = jax.nn.relu(conv2d(p["convs"][f"c{i}"], h, stride=stride))
    h = jnp.mean(h, axis=(1, 2))
    return h @ p["fc"]["w"] + p["fc"]["b"]


def cnn_fc_only_init(fac: PFac, cnn_cfg, stage: int) -> Params:
    """Ablation: naive FC-only output module (paper shows this hurts)."""
    c = cnn_cfg.stage_channels[stage]
    return {"fc": {"w": fac.param("fc_w", (c, cnn_cfg.num_classes), (None, None),
                                  init="normal"),
                   "b": fac.param("fc_b", (cnn_cfg.num_classes,), (None,), init="zeros")}}


def cnn_fc_only_apply(p: Params, h: jnp.ndarray) -> jnp.ndarray:
    h = jnp.mean(h, axis=(1, 2))
    return h @ p["fc"]["w"] + p["fc"]["b"]


# ---------------------------------------------------------------------------
# Overhead accounting (paper §V-B2: 2.8% memory, 7.3% compute)
# ---------------------------------------------------------------------------


def op_overhead(cfg: ArchConfig, stage: int, batch: int, seq: int) -> Dict[str, float]:
    from repro.core import memory_model as mm

    T = cfg.num_freeze_blocks
    n_op = max(T - stage - 1, 0)
    op_params = n_op * mm._proxy_layer_params(cfg) + cfg.d_model * cfg.vocab_size
    op_flops = n_op * mm.layer_fwd_flops_per_token(cfg, "attn_mlp", seq) * 0.5 \
        * batch * seq * 3
    stage_mem = mm.stage_memory_bytes(cfg, stage, batch, seq)["total"]
    stage_fl = mm.stage_flops(cfg, stage, batch, seq)["total"]
    return {"mem_fraction": op_params * mm.BYTES[cfg.param_dtype] / stage_mem,
            "flops_fraction": op_flops / stage_fl}
