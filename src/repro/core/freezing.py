"""Progressive training with layer freezing (paper §IV-A) for the LM zoo.

Stage t trains ONLY block t (layers [b_t, b_{t+1})) plus the output module;
the frozen prefix runs forward-only under a boundary ``stop_gradient``, so XLA
keeps no residuals for it and the optimizer holds no state for it — both
terms of the paper's Eq. (4) memory saving are structural here, visible in
``compiled.memory_analysis()`` of the stage step.

Parameter-tree mechanics: stacked scan leaves are *sliced* at block
boundaries into a frozen tree and an active tree; the stage forward stitches
them back together in execution order. zamba2's weight-tied shared-attention
sets stay in the active tree at every stage (tying spans blocks — DESIGN.md
§5); frozen-region occurrences contribute no gradient because of the boundary
stop_gradient.

``make_fed_round_step`` wraps the stage step into a federated round: pods are
cross-silo clients — broadcast, K local steps (lax.scan), dataset-weighted
parameter aggregation over the pod dimension (Eq. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import output_module as op_mod
from repro.models.module import PFac, Params, axes_to_tree, slice_stack
from repro.models.transformer import LM, chunked_ce_loss, layer_apply, token_loss
from repro.optim import Optimizer, apply_updates, clip_by_global_norm


class StagePlan(NamedTuple):
    stage: int
    lo: int
    hi: int
    train_embed: bool
    final: bool  # last stage: real final_norm + head instead of output module
    # execution order: list of (region, kind, seg_idx, a, b) with a/b relative
    # to the segment start; region in {"frozen", "active"}
    runs: tuple


def make_stage_plan(cfg: ArchConfig, stage: Optional[int]) -> StagePlan:
    """stage=None means full-model (vanilla) training."""
    T = cfg.num_freeze_blocks
    if stage is None:
        stage, lo, hi = T - 1, 0, cfg.num_layers
        final, train_embed = True, True
        bounds = None
    else:
        bounds = cfg.block_boundaries()
        lo, hi = bounds[stage], bounds[stage + 1]
        final = stage == T - 1
        train_embed = stage == 0
    runs = []
    pos = 0
    for si, (kind, n) in enumerate(cfg.segments()):
        s_lo, s_hi = pos, pos + n
        pos += n
        for region, r_lo, r_hi in (("frozen", 0, lo), ("active", lo, hi)):
            a, b = max(r_lo, s_lo), min(r_hi, s_hi)
            if a < b:
                runs.append((region, kind, si, a - s_lo, b - s_lo))
    return StagePlan(stage, lo, hi, train_embed, final, tuple(runs))


# ---------------------------------------------------------------------------
# Parameter splitting
# ---------------------------------------------------------------------------


def split_stage_params(model: LM, params: Params, plan: StagePlan
                       ) -> Tuple[Params, Params]:
    """Returns (frozen, active) partial trees. Both contain a 'runs' dict
    keyed by run index. Layers past plan.hi are NOT materialized (progressive
    growth: the model literally hasn't grown them yet)."""
    frozen: Params = {"runs": {}}
    active: Params = {"runs": {}}
    (active if plan.train_embed else frozen)["embed"] = params["embed"]
    if "frontend" in params:
        (active if plan.train_embed else frozen)["frontend"] = params["frontend"]
    for ri, (region, kind, si, a, b) in enumerate(plan.runs):
        tgt = active if region == "active" else frozen
        if kind == "shared_attn":
            continue  # tied sets handled below
        tgt["runs"][str(ri)] = slice_stack(params["segments"][str(si)], a, b)
    if "shared_attn" in params:
        active["shared_attn"] = params["shared_attn"]
    if plan.final:
        active["final_norm"] = params["final_norm"]
        if "head" in params:
            active["head"] = params["head"]
    return frozen, active


def split_stage_axes(model: LM, axes_tree: Dict, plan: StagePlan
                     ) -> Tuple[Dict, Dict]:
    """Like split_stage_params but for the logical-axes tree (leaves are
    tuples; slicing a layer range does not change a leaf's axes)."""
    frozen: Dict = {"runs": {}}
    active: Dict = {"runs": {}}
    (active if plan.train_embed else frozen)["embed"] = axes_tree["embed"]
    if "frontend" in axes_tree:
        (active if plan.train_embed else frozen)["frontend"] = axes_tree["frontend"]
    for ri, (region, kind, si, a, b) in enumerate(plan.runs):
        if kind == "shared_attn":
            continue
        tgt = active if region == "active" else frozen
        tgt["runs"][str(ri)] = axes_tree["segments"][str(si)]
    if "shared_attn" in axes_tree:
        active["shared_attn"] = axes_tree["shared_attn"]
    if plan.final:
        active["final_norm"] = axes_tree["final_norm"]
        if "head" in axes_tree:
            active["head"] = axes_tree["head"]
    return frozen, active


def merge_stage_params(model: LM, params: Params, plan: StagePlan,
                       active: Params) -> Params:
    """Write the trained active slices back into the full param tree."""
    new = jax.tree.map(lambda x: x, params)  # shallow copy per leaf
    if plan.train_embed:
        new["embed"] = active["embed"]
        if "frontend" in active:
            new["frontend"] = active["frontend"]
    for ri, (region, kind, si, a, b) in enumerate(plan.runs):
        if region != "active" or kind == "shared_attn":
            continue
        sl = active["runs"][str(ri)]

        def put(full, part):
            return full.at[a:b].set(part.astype(full.dtype))

        new["segments"][str(si)] = jax.tree.map(put, new["segments"][str(si)], sl)
    if "shared_attn" in active:
        new["shared_attn"] = active["shared_attn"]
    if plan.final:
        new["final_norm"] = active["final_norm"]
        if "head" in active:
            new["head"] = active["head"]
    return new


# ---------------------------------------------------------------------------
# Stage forward/loss
# ---------------------------------------------------------------------------


def _run(model: LM, h, run_params, kind: str, cfg: ArchConfig, *, remat: bool,
         remat_policy=None):
    causal = not cfg.is_encoder_only

    def one(hh, lp):
        hh, aux = layer_apply(lp, hh, cfg, kind, causal=causal)
        return hh, aux

    if remat and remat_policy is not None:
        body = jax.checkpoint(one, policy=remat_policy)
    elif remat:
        body = jax.checkpoint(one)
    else:
        body = one

    def scan_body(carry, lp):
        hh, aux = carry
        hh2, a = body(hh, lp)
        return (hh2, aux + a), None

    (h, aux), _ = jax.lax.scan(scan_body, (h, jnp.float32(0.0)), run_params)
    return h, aux


def prefix_is_static(plan: StagePlan) -> bool:
    """True when the frozen prefix is a fixed feature extractor for the whole
    stage — i.e. its outputs can be cached. False at stage 0 (the embedding
    trains, so 'prefix' outputs move every step) and when the prefix contains
    a weight-tied shared-attention segment (zamba2): those weights live in
    the ACTIVE tree at every stage and keep updating."""
    if plan.train_embed:
        return False
    return not any(kind == "shared_attn"
                   for region, kind, si, a, b in plan.runs
                   if region == "frozen")


def stage_prefix_features(model: LM, frozen: Params, active: Params,
                          batch: Dict, plan: StagePlan):
    """Embed + frozen-prefix forward only. Returns (hidden, aux_loss_so_far).

    The plan's runs list all frozen runs before any active run (the frozen
    region is layers [0, lo) and the active region [lo, hi)), so the prefix
    is a clean split point. When ``prefix_is_static(plan)`` the result is a
    pure function of the batch and can be cached across the stage's rounds
    — the round engine (fl/engine.py) exploits exactly that."""
    from repro.dist.sharding import shard_batch

    cfg = model.cfg
    src = active if plan.train_embed else frozen
    h = shard_batch(model.embed(src, batch), batch_axes=cfg.batch_axes)
    aux_total = jnp.float32(0.0)
    for ri, (region, kind, si, a, b) in enumerate(plan.runs):
        if region == "active":
            break
        if kind == "shared_attn":
            sp = active["shared_attn"][str(_shared_idx(model, si))]
            h, aux = layer_apply(sp, h, cfg, kind, causal=not cfg.is_encoder_only)
        else:
            h, aux = _run(model, h, frozen["runs"][str(ri)], kind, cfg,
                          remat=False)
        aux_total = aux_total + aux
    return h, aux_total


def stage_forward_from_features(model: LM, active: Params, h, aux_total,
                                plan: StagePlan, *, remat: bool = True,
                                remat_policy=None):
    """Active-suffix forward from (possibly cached) prefix features. Applies
    the stop-gradient memory boundary, the active runs, and the final-norm
    head or output module. Returns (hidden, head_w, aux_loss)."""
    cfg = model.cfg
    h = jax.lax.stop_gradient(h)  # memory boundary: no bwd into prefix
    for ri, (region, kind, si, a, b) in enumerate(plan.runs):
        if region != "active":
            continue
        if kind == "shared_attn":
            sp = active["shared_attn"][str(_shared_idx(model, si))]
            h, aux = layer_apply(sp, h, cfg, kind, causal=not cfg.is_encoder_only)
        else:
            h, aux = _run(model, h, active["runs"][str(ri)], kind, cfg,
                          remat=remat, remat_policy=remat_policy)
        aux_total = aux_total + aux
    if plan.final:
        from repro.models.layers import norm
        h = norm(active["final_norm"], h, cfg.norm, cfg.norm_eps)
        head_w = (active["embed"].T if cfg.tie_embeddings
                  else active["head"]["w"])
    else:
        h = op_mod.lm_op_hidden(active["op"], h, cfg)
        head_w = active["op"]["head"]["w"]
    return h, head_w, aux_total


def stage_forward(model: LM, frozen: Params, active: Params, batch: Dict,
                  plan: StagePlan, *, remat: bool = True, remat_policy=None):
    """Returns (hidden, head_w, aux_loss) — the head matmul is folded into the
    chunked CE loss so [B, S, V] logits are never materialized. Composes
    ``stage_prefix_features`` + ``stage_forward_from_features`` so the cached
    path is numerically identical to full recompute by construction."""
    h, aux = stage_prefix_features(model, frozen, active, batch, plan)
    return stage_forward_from_features(model, active, h, aux, plan,
                                       remat=remat, remat_policy=remat_policy)


def _shared_idx(model: LM, seg_idx: int) -> int:
    """Tied-set index for the shared_attn segment seg_idx."""
    occ = 0
    for i, (kind, n) in enumerate(model.cfg.segments()):
        if i == seg_idx:
            break
        if kind == "shared_attn":
            occ += 1
    return occ % max(model.cfg.num_shared_attn_sets, 1)


def stage_logits(model: LM, frozen: Params, active: Params, batch: Dict,
                 plan: StagePlan, *, remat: bool = True):
    """Full logits (tests / small models only)."""
    h, head_w, aux = stage_forward(model, frozen, active, batch, plan, remat=remat)
    return h @ head_w.astype(h.dtype), aux


def stage_loss_fn(model: LM, plan: StagePlan, *, remat: bool = True,
                  remat_policy=None):
    def loss_fn(active: Params, frozen: Params, batch: Dict) -> jnp.ndarray:
        h, head_w, aux = stage_forward(model, frozen, active, batch, plan,
                                       remat=remat, remat_policy=remat_policy)
        return chunked_ce_loss(h, head_w, batch, model.cfg) + 0.01 * aux

    return loss_fn


def cached_stage_loss_fn(model: LM, plan: StagePlan, *, remat: bool = True,
                         remat_policy=None):
    """Stage loss over cached prefix features: the batch carries ``h0`` (the
    prefix output) and ``aux0`` (the prefix's frozen aux loss, a constant)
    alongside the usual label/mask keys; no frozen tree is consumed."""
    def loss_fn(active: Params, batch: Dict) -> jnp.ndarray:
        h, head_w, aux = stage_forward_from_features(
            model, active, batch["h0"], batch["aux0"], plan, remat=remat,
            remat_policy=remat_policy)
        return chunked_ce_loss(h, head_w, batch, model.cfg) + 0.01 * aux

    return loss_fn


def init_stage_active(model: LM, params: Params, plan: StagePlan, rng) -> Tuple[Params, Params]:
    """(frozen, active) with a freshly-initialized output module when needed."""
    frozen, active = split_stage_params(model, params, plan)
    if not plan.final:
        fac = PFac(rng, dtype=jnp.bfloat16)
        active["op"] = op_mod.lm_op_init(fac.sub("op"), model.cfg, plan.stage)
    return frozen, active


# ---------------------------------------------------------------------------
# Train steps
# ---------------------------------------------------------------------------


class TrainState(NamedTuple):
    active: Params
    frozen: Params
    opt_state: Any
    step: jnp.ndarray


def make_train_step(model: LM, plan: StagePlan, optimizer: Optimizer, *,
                    remat: bool = True, clip_norm: float = 1.0):
    """Centralized (single-cohort) stage train step."""
    loss_fn = stage_loss_fn(model, plan, remat=remat)

    def step(state: TrainState, batch: Dict):
        loss, grads = jax.value_and_grad(loss_fn)(state.active, state.frozen, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        ups, opt_state = optimizer.update(grads, state.opt_state, state.active)
        active = apply_updates(state.active, ups)
        return TrainState(active, state.frozen, opt_state, state.step + 1), \
            {"loss": loss, "grad_norm": gnorm}

    return step


def make_fed_round_step(model: LM, plan: StagePlan, local_opt: Optimizer, *,
                        num_pods: int, local_steps: int, remat: bool = True,
                        clip_norm: float = 1.0, constrain_podded=None,
                        remat_policy=None):
    """One federated round (Eq. 1) with pods as cross-silo clients.

    Inputs: global active params (no pod dim), frozen params (replicated),
    batch with leading dims [num_pods, local_steps, ...] (pod-sharded), and
    per-pod example weights [num_pods].

    Broadcast -> vmap(pod-local K-step SGD scan) -> weighted parameter
    average over the pod dim (the Eq. 1 all-reduce; GSPMD lowers the mean to
    a cross-pod collective because the pod dim is sharded on the "pod" axis).
    """
    loss_fn = stage_loss_fn(model, plan, remat=remat,
                            remat_policy=remat_policy)

    def local_train(active, frozen, batches):
        opt_state = local_opt.init(active)

        def one(carry, batch):
            act, ost = carry
            loss, grads = jax.value_and_grad(loss_fn)(act, frozen, batch)
            grads, _ = clip_by_global_norm(grads, clip_norm)
            ups, ost = local_opt.update(grads, ost, act)
            return (apply_updates(act, ups), ost), loss

        (active, _), losses = jax.lax.scan(one, (active, opt_state), batches)
        return active, jnp.mean(losses)

    def round_step(active: Params, frozen: Params, batch: Dict,
                   weights: jnp.ndarray):
        podded = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (num_pods,) + x.shape), active)
        if constrain_podded is not None:
            podded = constrain_podded(podded)
        podded, losses = jax.vmap(local_train, in_axes=(0, None, 0))(
            podded, frozen, batch)
        w = (weights / jnp.sum(weights)).astype(jnp.float32)

        def agg(x):
            return jnp.einsum("p,p...->...", w, x.astype(jnp.float32)).astype(x.dtype)

        new_active = jax.tree.map(agg, podded)
        return new_active, {"loss": jnp.sum(w * losses)}

    return round_step
