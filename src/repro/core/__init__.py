from repro.core.freezing import (StagePlan, make_stage_plan, split_stage_params,
                                 merge_stage_params, stage_forward, stage_loss_fn,
                                 init_stage_active, make_train_step,
                                 make_fed_round_step, TrainState)
from repro.core.pace import PaceController
from repro.core.selector import ParticipantSelector, ClientInfo, rlcd_communities
