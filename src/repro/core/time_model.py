"""Stage-based system time model — Eqs. (5)-(7).

t_t^i = rho * FLOPs_t * |D_i| / c_i          (Eq. 6)
T_r(S, t) = max_{i in S} t_t^i              (Eq. 7, synchronous round)

``c_i`` is the device's runtime training capability (FLOP/s it actually
sustains, reported by the local monitor); ``rho`` a calibration coefficient
determined offline (paper §IV-C2). The same model drives straggler-aware
selection and the deadline used for partial aggregation.
"""
from __future__ import annotations

from typing import Dict, Iterable, Sequence

from repro.core.memory_model import stage_flops, full_model_flops


def client_stage_time(cfg, stage: int, num_samples: int, capability_flops: float,
                      *, batch: int = 1, seq: int = 1, rho: float = 1.0) -> float:
    """Eq. (6): seconds for client i to finish stage-t local training."""
    per_sample = stage_flops(cfg, stage, batch, seq)["total"] / max(batch, 1)
    return rho * per_sample * num_samples / capability_flops


def round_time(cfg, stage: int, clients: Sequence[Dict], *,
               batch: int = 1, seq: int = 1, rho: float = 1.0) -> float:
    """Eq. (7): synchronous round time = slowest selected client."""
    return max(client_stage_time(cfg, stage, c["num_samples"], c["capability"],
                                 batch=batch, seq=seq, rho=rho)
               for c in clients)


def stage_speedup(cfg, stage: int, *, batch: int = 1, seq: int = 128) -> float:
    """FLOPs speedup of stage-t training vs full-model training (paper: up to
    2.02x across the whole schedule)."""
    full = full_model_flops(cfg, batch, seq)
    st = stage_flops(cfg, stage, batch, seq)["total"]
    return full / st
