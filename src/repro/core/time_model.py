"""Stage-based system time model — Eqs. (5)-(7).

t_t^i = rho * FLOPs_t * |D_i| / c_i          (Eq. 6)
T_r(S, t) = max_{i in S} t_t^i              (Eq. 7, synchronous round)

``c_i`` is the device's runtime training capability (FLOP/s it actually
sustains, reported by the local monitor); ``rho`` a calibration coefficient
determined offline (paper §IV-C2). The same model drives straggler-aware
selection and the deadline used for partial aggregation.

Scalar entry points (``client_stage_time`` / ``round_time``) serve the
list-based control path; the ``*_vec`` kernels below are the vectorized,
device-resident form used by the virtual-time simulation core
(``fl/sim.py``) over ``ClientPopulation``-style arrays: per-client compute
times, heterogeneous uplink times for a payload, and a deterministic
per-(client, round) lognormal jitter so availability traces replay
bit-identically across checkpoint/resume.
"""
from __future__ import annotations

from typing import Dict, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memory_model import (full_model_flops,
                                     layer_fwd_flops_per_token, stage_flops)


def client_stage_time(cfg, stage: int, num_samples: int, capability_flops: float,
                      *, batch: int = 1, seq: int = 1, rho: float = 1.0) -> float:
    """Eq. (6): seconds for client i to finish stage-t local training."""
    per_sample = stage_flops(cfg, stage, batch, seq)["total"] / max(batch, 1)
    return rho * per_sample * num_samples / capability_flops


def round_time(cfg, stage: int, clients: Sequence[Dict], *,
               batch: int = 1, seq: int = 1, rho: float = 1.0) -> float:
    """Eq. (7): synchronous round time = slowest selected client.

    An empty cohort (reachable when every selected client drops out, or via
    ``InfeasibleStageError`` recovery paths that retry with no survivors)
    contributes no wall-clock: the round is a no-op and costs 0.0 rather
    than raising ``max() arg is an empty sequence``."""
    clients = list(clients)
    if not clients:
        return 0.0
    return max(client_stage_time(cfg, stage, c["num_samples"], c["capability"],
                                 batch=batch, seq=seq, rho=rho)
               for c in clients)


def stage_speedup(cfg, stage: int, *, batch: int = 1, seq: int = 128) -> float:
    """FLOPs speedup of stage-t training vs full-model training (paper: up to
    2.02x across the whole schedule)."""
    full = full_model_flops(cfg, batch, seq)
    st = stage_flops(cfg, stage, batch, seq)["total"]
    return full / st


def cnn_cached_compute_scale(stage: int) -> float:
    """Fraction of a stage-``stage`` CNN local step that remains when the
    frozen prefix is served from the feature cache (fl/engine.py) instead
    of recomputed per minibatch.

    CNN ladders double channels while halving resolution per stage, so
    per-stage forward cost is roughly constant: a recompute step costs
    ~``stage`` prefix-forward units plus fwd+bwd (~3 units) on the active
    stage, a cached step just the 3 active units — scale 3 / (stage + 3).
    Stage 0 has no prefix (scale 1). Feeds
    ``FleetTimeModel.with_compute_scale`` so tier admission shows up on the
    virtual clock and in deadline-policy cohort composition.
    """
    return 3.0 / (max(stage, 0) + 3.0)


def lm_cached_compute_scale(cfg, stage: int, *, batch: int = 1,
                            seq: int = 128) -> float:
    """LM twin of ``cnn_cached_compute_scale``, exact under Eq. 5: a cached
    step drops the frozen-prefix forward term from the stage FLOPs."""
    fl = stage_flops(cfg, stage, batch, seq)
    lo = cfg.block_boundaries()[stage]
    kinds = cfg.layer_kinds()
    tok = batch * seq
    fwd_frozen = sum(layer_fwd_flops_per_token(cfg, kinds[i], seq)
                     for i in range(lo)) * tok
    return max(fl["total"] - fwd_frozen, 0.0) / fl["total"]


# ---------------------------------------------------------------------------
# Vectorized time kernels (fl/sim.py's device-resident hot path)
# ---------------------------------------------------------------------------


@jax.jit
def stage_times_vec(flops_per_sample, num_samples, capability, rho=1.0):
    """Eq. (6) over client arrays: [N] seconds of local compute.

    ``flops_per_sample`` may be a scalar (one stage for the whole fleet) or
    an [N] array (per-client sub-models, e.g. DepthFL/HeteroFL)."""
    return (rho * flops_per_sample * num_samples.astype(jnp.float32)
            / jnp.maximum(capability, 1e-9))


@jax.jit
def uplink_times_vec(payload_bytes, link_rate):
    """[N] seconds to put ``payload_bytes`` on each client's uplink.
    ``jnp.inf`` link rates (the default "free network" model) cost 0."""
    rate = jnp.maximum(link_rate, 1e-9)
    t = payload_bytes / rate
    return jnp.where(jnp.isinf(link_rate), 0.0, t)


def completion_jitter(n: int, seed: int, round_idx: int,
                      sigma: float) -> np.ndarray:
    """[n] multiplicative lognormal jitter, deterministic per
    (seed, round) — replays identically across checkpoint/resume, which is
    what keeps restored virtual-time trajectories bit-identical."""
    if sigma <= 0.0:
        return np.ones(n, np.float32)
    rng = np.random.RandomState((seed * 1_000_003 + round_idx) % (2 ** 32))
    return np.exp(rng.randn(n).astype(np.float32) * sigma
                  - 0.5 * sigma * sigma)


@jax.jit
def completion_times_vec(compute_s, uplink_s, jitter):
    """Per-client round completion time: jittered compute + uplink."""
    return compute_s * jitter + uplink_s


def cohort_round_time(times: Sequence[float]) -> float:
    """Eq. (7) over precomputed completion times; empty cohort -> 0.0."""
    times = np.asarray(list(times), np.float64)
    if times.size == 0:
        return 0.0
    return float(times.max())
