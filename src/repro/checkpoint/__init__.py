from repro.checkpoint.ckpt import (save_checkpoint, restore_checkpoint,
                                   CheckpointManager, latest_step)
