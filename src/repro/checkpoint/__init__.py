from repro.checkpoint.ckpt import (save_checkpoint, restore_checkpoint,
                                   CheckpointManager, CheckpointCorruptError,
                                   latest_step)
