"""Fault-tolerant checkpointing: atomic, sharded, async, elastic.

Layout:  <dir>/step_<N>/
            manifest.json      tree structure + shapes/dtypes + metadata
            <leaf-path>.npy    one file per leaf (per host on multi-host)
         <dir>/step_<N>.COMMIT   written LAST -> restart-safe atomicity

Restores re-shard onto whatever mesh the new run uses (shardings are applied
by the caller via device_put, so pod counts can change between runs — elastic
scaling). An async mode hands the host-transfer + write to a daemon thread so
the train loop never blocks on I/O.

Durability (ISSUE 7): every leaf carries a crc32 in the manifest, verified on
restore — a bit-flipped or truncated .npy is detected, not silently loaded.
``restore_checkpoint(step=None)`` and ``latest_step`` walk committed steps
newest-first and skip torn directories (COMMIT present but manifest/leaves
missing or corrupt — e.g. a crash between rename and COMMIT of a *previous*
layout, or post-hoc disk damage), falling back to the last good step. Async
save failures are captured and re-raised on ``wait()`` or the next ``save()``
so a failed background write can't masquerade as a committed checkpoint.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

from repro.models.module import tree_paths

# numpy can't natively (de)serialize bf16/fp8 — store raw uint16/uint8 views
# and record the logical dtype in the manifest
_RAW_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}
_ML_DTYPES = {"bfloat16": ml_dtypes.bfloat16,
              "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
              "float8_e5m2": ml_dtypes.float8_e5m2}

_log = logging.getLogger(__name__)


class CheckpointCorruptError(RuntimeError):
    """A committed step failed integrity verification (crc/manifest/leaf)."""


def _leaf_file(path) -> str:
    return "__".join(str(p) for p in path) + ".npy"


def pack_ragged(lists) -> Dict[str, np.ndarray]:
    """A list of int lists as two checkpointable arrays (values + offsets).
    Shared encoding for fitted selector communities (fl/sim.py and
    core/selector/vectorized.py serialize through this)."""
    flat = np.asarray([v for sub in lists for v in sub], np.int64)
    offsets = np.cumsum([0] + [len(sub) for sub in lists]).astype(np.int64)
    return {"flat": flat, "offsets": offsets}


def unpack_ragged(tree: Dict[str, np.ndarray]) -> List[List[int]]:
    flat = np.asarray(tree["flat"])
    offs = np.asarray(tree["offsets"])
    return [[int(v) for v in flat[offs[i]:offs[i + 1]]]
            for i in range(len(offs) - 1)]


def _json_safe(obj):
    """Coerce numpy scalars/arrays hiding in metadata to plain JSON types —
    simulation callers checkpoint virtual clocks / round counters that often
    arrive as np.float32 / np.int64."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    metadata: Optional[Dict] = None) -> str:
    """Atomic synchronous save. Returns the commit marker path."""
    step_dir = os.path.join(ckpt_dir, f"step_{step}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)
    manifest = {"step": step, "leaves": [], "metadata": _json_safe(metadata or {})}
    for path, leaf in tree_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if logical_dtype in _RAW_VIEW:
            arr = arr.view(_RAW_VIEW[logical_dtype])
        fname = _leaf_file(path)
        np.save(os.path.join(tmp_dir, fname), arr)
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        manifest["leaves"].append({"path": list(path), "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": logical_dtype,
                                   "crc32": int(crc)})
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    commit = step_dir + ".COMMIT"
    with open(commit, "w") as f:
        f.write("ok")
    return commit


def _committed_steps(ckpt_dir: str) -> List[int]:
    """Step numbers with a COMMIT marker (no integrity check)."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.endswith(".COMMIT"):
            try:
                steps.append(int(name[len("step_"):-len(".COMMIT")]))
            except ValueError:
                continue
    return steps


def _step_intact(ckpt_dir: str, step: int) -> bool:
    """Cheap structural check: manifest readable, every leaf file present.
    Content checksums are verified (per-leaf) at load time."""
    step_dir = os.path.join(ckpt_dir, f"step_{step}")
    try:
        with open(os.path.join(step_dir, "manifest.json")) as f:
            manifest = json.load(f)
        return all(os.path.isfile(os.path.join(step_dir, e["file"]))
                   for e in manifest["leaves"])
    except (OSError, ValueError, KeyError, TypeError):
        return False


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest committed step whose directory is structurally intact.

    A COMMIT marker whose step dir was torn (deleted leaves, truncated or
    missing manifest) is skipped with a warning instead of being returned
    and then exploding at restore time.
    """
    for step in sorted(_committed_steps(ckpt_dir), reverse=True):
        if _step_intact(ckpt_dir, step):
            return step
        _log.warning("checkpoint step_%d is committed but torn; skipping", step)
    return None


def _load_step(ckpt_dir: str, step: int, shardings: Any = None) -> Dict:
    """Load one committed step, verifying per-leaf crc32 where recorded.

    Raises ``CheckpointCorruptError`` on checksum mismatch, ``OSError`` /
    ``ValueError`` on missing or unreadable files.
    """
    step_dir = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    tree: Dict = {}
    shard_list = None
    if shardings is not None:
        shard_list = {tuple(p): s for p, s in
                      ((path, leaf) for path, leaf in tree_paths(shardings))}
    for entry in manifest["leaves"]:
        arr = np.load(os.path.join(step_dir, entry["file"]))
        # crc is computed over the raw on-disk view (pre bf16/fp8 reinterpret);
        # manifests from before ISSUE 7 carry no crc and skip verification
        want = entry.get("crc32")
        if want is not None:
            got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if got != int(want):
                raise CheckpointCorruptError(
                    f"step_{step}/{entry['file']}: crc32 mismatch "
                    f"(manifest {int(want)}, file {got})")
        if entry["dtype"] in _ML_DTYPES:
            arr = arr.view(_ML_DTYPES[entry["dtype"]])
        path = tuple(entry["path"])
        if shard_list is not None and path in shard_list:
            arr = jax.device_put(arr, shard_list[path])
        node = tree
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = arr
    return {"tree": tree, "step": step, "metadata": manifest["metadata"]}


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None, *,
                       shardings: Any = None) -> Dict:
    """Returns {"tree": nested dict, "step": int, "metadata": dict}.

    If ``shardings`` (a pytree of jax.sharding.Sharding matching the saved
    tree) is given, leaves are device_put onto it — this is the elastic
    re-shard path: the target mesh may differ from the saving run's mesh.

    With ``step=None``, committed steps are tried newest-first: a step that
    fails integrity verification (torn dir, unreadable manifest, crc32
    mismatch) is skipped with a warning and the previous committed step is
    loaded instead. An explicitly requested ``step`` raises on any failure —
    the caller asked for that exact state, silently substituting another
    would be worse than failing.
    """
    if step is not None:
        return _load_step(ckpt_dir, step, shardings)
    candidates = sorted(_committed_steps(ckpt_dir), reverse=True)
    for s in candidates:
        try:
            return _load_step(ckpt_dir, s, shardings)
        except (OSError, ValueError, KeyError, CheckpointCorruptError) as e:
            _log.warning("checkpoint step_%d unusable (%s); falling back to "
                         "previous committed step", s, e)
    raise FileNotFoundError(f"no usable committed checkpoint in {ckpt_dir}")


class CheckpointManager:
    """Retention + async saves + resume."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3, async_save: bool = True):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        # snapshot to host BEFORE handing to the thread (values keep training)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree,
                                metadata=metadata)
                self._gc()
            except BaseException as e:  # surfaced on wait()/next save()
                self._error = e

        if self.async_save:
            self.wait()  # re-raises a previous background failure
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.ckpt_dir, step, host_tree, metadata=metadata)
            self._gc()

    def wait(self):
        """Block until the in-flight save lands; re-raise its failure.

        A background exception (disk full, permission error) must not be
        swallowed: the caller would otherwise treat the step as committed
        and happily delete older, actually-durable checkpoints.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    def restore(self, step: Optional[int] = None, shardings=None) -> Dict:
        self.wait()
        return restore_checkpoint(self.ckpt_dir, step, shardings=shardings)

    def _gc(self):
        steps = sorted(s for s in self._committed())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)
            try:
                os.remove(os.path.join(self.ckpt_dir, f"step_{s}.COMMIT"))
            except FileNotFoundError:
                pass

    def _committed(self) -> List[int]:
        return _committed_steps(self.ckpt_dir)
