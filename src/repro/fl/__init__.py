from repro.fl.client import SimClient, make_client_fleet
from repro.fl.server import SmartFreezeServer, FedAvgServer, RoundResult
from repro.fl.compression import topk_compress, topk_decompress, ErrorFeedback
