from repro.fl.client import SimClient, make_client_fleet
from repro.fl.engine import (RoundEngine, make_fused_round,
                             make_lm_cached_fed_round_step, weighted_avg)
from repro.fl.sim import (AsyncBufferedAggregation, AvailabilityTrace,
                          DeadlineAggregation, FederatedLoop, FleetTimeModel,
                          RoundRecord, SyncAggregation)
from repro.fl.server import SmartFreezeServer, FedAvgServer, RoundResult
from repro.fl.compression import topk_compress, topk_decompress, ErrorFeedback
from repro.fl.quant import (CACHE_TIERS, EncodedFeatures, decode_features,
                            dequantize_int8, encode_features, quantize_int8)
