"""The paper's six baselines (§V-A), implemented for the CNN testbed.

AllSmall     — width-scale the whole model to the minimum client memory.
ExclusiveFL  — vanilla FedAvg, only clients that fit the FULL model.
DepthFL      — depth-scaled submodels + auxiliary classifiers, per-stage agg.
HeteroFL     — per-client width scaling, overlapping-slice aggregation.
TiFL         — tier clients by round time, sample within a tier.
Oort         — utility-based selection (stat util x time penalty).

Each returns the same history format as the servers in fl/server.py so the
benchmark harness plots them together (paper Figs. 7-8 / Table I).

Local training runs through ``fl/engine.py`` (homogeneous baselines fuse the
whole cohort into one dispatch; DepthFL/HeteroFL fuse per depth/scale group)
and round orchestration through ``fl/sim.py``'s ``FederatedLoop`` — the same
virtual-time loop the servers use, so every baseline accepts ``aggregation``
("sync" Eq. 7 barrier or "deadline" partial aggregation; the submodel
baselines have no single-model async hooks), ``time_model`` and
``availability`` and reports per-round virtual durations in its history.
TiFL/Oort charge their full-model payload against client uplinks like the
servers do; DepthFL/HeteroFL cohorts upload per-client *submodels*, so
callers wanting uplink-time accounting there pass a ``time_model`` with
``payload_bytes`` set to their scenario's effective payload. Every baseline
also takes ``compute_dtype`` (e.g. ``"bfloat16"``) — the engine's
mixed-precision local-training knob with f32 master params (fl/engine.py).

Fault tolerance (ISSUE 7): every baseline accepts ``faults`` (a
``fl.faults.FaultInjector``), ``screen_updates`` and ``aggregator``
("mean" | "trimmed_mean" | "coord_median"), threaded into the shared
``FederatedLoop`` / ``RoundEngine`` exactly like the servers — so robustness
comparisons against SmartFreeze run every method under the same
deterministic fault schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import freezing_cnn as fz
from repro.core.memory_model import cnn_stage_memory_bytes
from repro.core.output_module import cnn_fc_only_apply, cnn_fc_only_init
from repro.fl.client import SimClient
from repro.fl.engine import RoundEngine, weighted_avg
from repro.fl.server import FedAvgServer, RoundResult, _mean_loss
from repro.fl.sim import FederatedLoop, FleetTimeModel
from repro.models.cnn import CNN, CNNConfig
from repro.models.module import PFac
from repro.optim import sgd


def full_model_memory(model: CNN, batch_size: int) -> float:
    n = len(model.cfg.stage_sizes)
    return sum(cnn_stage_memory_bytes(model, s, batch_size) for s in range(n))


def scaled_config(cfg: CNNConfig, scale: float) -> CNNConfig:
    chans = tuple(max(int(c * scale), 4) for c in cfg.stage_channels)
    return dataclasses.replace(cfg, stage_channels=chans,
                               name=f"{cfg.name}_x{scale:g}")


def _run_loop(clients_by_id, select_fn, train_fn, on_round, rounds, *,
              aggregation="sync", time_model=None, availability=None,
              faults=None):
    """One-liner over ``FederatedLoop`` shared by the baseline runners."""
    loop = FederatedLoop(select_fn=select_fn, train_fn=train_fn,
                         clients=clients_by_id,
                         client_ids=list(clients_by_id),
                         aggregation=aggregation, time_model=time_model,
                         availability=availability, on_round=on_round,
                         faults=faults)
    loop.run(rounds)
    return loop


# ---------------------------------------------------------------------------
# AllSmall
# ---------------------------------------------------------------------------


def run_allsmall(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
                 batch_size: int = 32, eval_fn=None, seed: int = 0, **kw) -> Dict:
    """Scale channels until the model fits the SMALLEST client memory."""
    min_mem = min(c.memory_bytes for c in clients)
    scale = 1.0
    while scale > 0.05:
        model = CNN(scaled_config(cfg, scale))
        if full_model_memory(model, batch_size) <= min_mem:
            break
        scale *= 0.5
    model = CNN(scaled_config(cfg, scale))
    params, state = model.init(jax.random.PRNGKey(seed))
    srv = FedAvgServer(model, clients, batch_size=batch_size, seed=seed, **kw)
    out = srv.run(params, state, rounds=rounds,
                  eval_fn=(lambda p, s, st: eval_fn(model, p, s)) if eval_fn else None)
    out["scale"] = scale
    out["model"] = model
    return out


# ---------------------------------------------------------------------------
# ExclusiveFL
# ---------------------------------------------------------------------------


def run_exclusivefl(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
                    batch_size: int = 32, eval_fn=None, seed: int = 0, **kw) -> Dict:
    model = CNN(cfg)
    req = full_model_memory(model, batch_size)
    eligible = [c for c in clients if c.memory_bytes >= req]
    out: Dict = {"participation": len(eligible) / len(clients), "history": []}
    if not eligible:
        out["inoperative"] = True  # paper: ResNet18/VGG16 scenarios
        return out
    params, state = model.init(jax.random.PRNGKey(seed))
    srv = FedAvgServer(model, clients, batch_size=batch_size,
                       mem_required=req, seed=seed, **kw)
    res = srv.run(params, state, rounds=rounds,
                  eval_fn=(lambda p, s, st: eval_fn(model, p, s)) if eval_fn else None)
    res["participation"] = out["participation"]
    res["model"] = model
    return res


# ---------------------------------------------------------------------------
# DepthFL
# ---------------------------------------------------------------------------


def run_depthfl(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
                batch_size: int = 32, clients_per_round: int = 10,
                eval_fn=None, seed: int = 0, local_epochs: int = 1,
                fused: bool = True, compress_ratio=None, compute_dtype=None,
                aggregation="sync", time_model=None, availability=None,
                screen_updates: bool = False, aggregator: str = "mean",
                faults=None) -> Dict:
    """Depth-scaled submodels: client c trains stages [0..d_c) + aux head."""
    model = CNN(cfg)
    n_stages = len(cfg.stage_sizes)
    params, state = model.init(jax.random.PRNGKey(seed))
    clients_by_id = {c.client_id: c for c in clients}
    # aux classifier per non-final depth
    fac = PFac(jax.random.PRNGKey(seed + 1), dtype=jnp.float32)
    aux = {d: cnn_fc_only_init(fac.sub(f"aux{d}"), cfg, d) for d in range(n_stages - 1)}

    # assign depth by memory
    depths = {}
    for c in clients:
        d = 0
        for s in range(n_stages):
            need = sum(cnn_stage_memory_bytes(model, t, batch_size) for t in range(s + 1))
            if c.memory_bytes >= need:
                d = s
        depths[c.client_id] = d
    participation = np.mean([depths[c.client_id] == n_stages - 1 for c in clients])

    def make_engine(depth: int) -> RoundEngine:
        def loss_fn(p, frozen_unused, st, batch):
            h = batch["x"]
            if cfg.kind == "resnet":
                h, st = model.stem(p, st, h, train=True)
            h, st = model.run_stages(p, st, h, 0, depth + 1, train=True)
            logits = model.head(p, h) if depth == n_stages - 1 \
                else cnn_fc_only_apply(p["aux"], h)
            return fz.softmax_xent(logits, batch["y"]), st

        return RoundEngine(loss_fn=loss_fn, optimizer=sgd(0.05),
                           batch_size=batch_size, local_epochs=local_epochs,
                           fused=fused, compress_ratio=compress_ratio,
                           compute_dtype=compute_dtype,
                           screen=screen_updates, aggregator=aggregator)

    engines = {d: make_engine(d) for d in range(n_stages)}
    rng = np.random.RandomState(seed)
    history: List[RoundResult] = []
    box = {"params": params, "state": state}

    def select_fn(r, avail):
        return list(rng.choice(avail, size=min(clients_per_round, len(avail)),
                               replace=False))

    def train_fn(sel, r, sequential=None, faults=None):
        params, state = box["params"], box["state"]
        # one fused dispatch per depth group (shapes are homogeneous within)
        by_depth: Dict[int, List[int]] = {}
        for cid in sel:
            by_depth.setdefault(depths[cid], []).append(cid)
        group_out: Dict[int, Dict] = {}
        losses: Dict[int, float] = {}
        for d, cids in by_depth.items():
            sub = {k: params[k] for k in params if k != "fc"}
            if d == n_stages - 1:
                sub["fc"] = params["fc"]
            else:
                sub["aux"] = aux[d]
            f_g = ({c: k for c, k in faults.items() if c in cids}
                   if faults else None) or None
            p_g, s_g, l_g = engines[d].run_round(clients_by_id, cids, sub,
                                                 state, r,
                                                 sequential=sequential,
                                                 faults=f_g)
            W_g = float(sum(clients_by_id[c].num_samples for c in cids))
            group_out[d] = {"params": p_g, "state": s_g, "weight": W_g}
            losses.update(l_g)
        # per-stage aggregation over depth groups that trained the stage
        new_params = dict(params)
        new_params["stages"] = dict(new_params["stages"])
        for s in range(n_stages):
            having = [g for d, g in group_out.items() if d >= s]
            if not having:
                continue
            ws = np.asarray([g["weight"] for g in having])
            ws = ws / ws.sum()
            new_params["stages"][f"stage{s}"] = weighted_avg(
                [g["params"]["stages"][f"stage{s}"] for g in having], ws)
        ws_all = np.asarray([g["weight"] for g in group_out.values()])
        ws_all = ws_all / ws_all.sum()
        if cfg.kind == "resnet":
            new_params["stem"] = weighted_avg(
                [g["params"]["stem"] for g in group_out.values()], ws_all)
        if n_stages - 1 in group_out:
            new_params["fc"] = group_out[n_stages - 1]["params"]["fc"]
        for d in range(n_stages - 1):
            if d in group_out:
                aux[d] = group_out[d]["params"]["aux"]
        box["params"] = new_params
        box["state"] = weighted_avg([g["state"] for g in group_out.values()],
                                    ws_all)
        return losses

    def on_round(rec):
        rr = RoundResult(rec.round_idx, n_stages - 1,
                         _mean_loss(rec.losses,
                                    prev=history[-1].loss if history else None),
                         selected=rec.selected, duration=rec.duration,
                         virtual_time=rec.t_end, dropped=rec.dropped)
        if eval_fn is not None and rec.round_idx % 10 == 0:
            rr.test_acc = eval_fn(model, box["params"], box["state"])
        history.append(rr)
        return False

    _run_loop(clients_by_id, select_fn, train_fn, on_round, rounds,
              aggregation=aggregation, time_model=time_model,
              availability=availability, faults=faults)
    return {"params": box["params"], "state": box["state"], "history": history,
            "participation": float(participation), "model": model}


# ---------------------------------------------------------------------------
# HeteroFL
# ---------------------------------------------------------------------------


_HFL_SCALES = (1.0, 0.5, 0.25, 0.125)


def _slice_like(full, small):
    """Upper-left slice of `full` with `small`'s shape."""
    slices = tuple(slice(0, s) for s in small.shape)
    return full[slices]


def run_heterofl(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
                 batch_size: int = 32, clients_per_round: int = 10,
                 eval_fn=None, seed: int = 0, local_epochs: int = 1,
                 fused: bool = True, compress_ratio=None, compute_dtype=None,
                 aggregation="sync", time_model=None, availability=None,
                 screen_updates: bool = False, aggregator: str = "mean",
                 faults=None) -> Dict:
    model_full = CNN(cfg)
    params_full, state_full = model_full.init(jax.random.PRNGKey(seed))
    clients_by_id = {c.client_id: c for c in clients}
    # assign the largest scale whose model fits each client
    scale_of = {}
    models = {s: CNN(scaled_config(cfg, s)) for s in _HFL_SCALES}
    for c in clients:
        sc = _HFL_SCALES[-1]
        for s in _HFL_SCALES:
            if full_model_memory(models[s], batch_size) <= c.memory_bytes:
                sc = s
                break
        scale_of[c.client_id] = sc

    def make_engine(scale) -> RoundEngine:
        model_s = models[scale]

        def loss_fn(p, frozen_unused, st, batch):
            return model_s.loss(p, st, batch, train=True)

        return RoundEngine(loss_fn=loss_fn, optimizer=sgd(0.05),
                           batch_size=batch_size, local_epochs=local_epochs,
                           fused=fused, compress_ratio=compress_ratio,
                           compute_dtype=compute_dtype,
                           screen=screen_updates, aggregator=aggregator)

    engines = {s: make_engine(s) for s in _HFL_SCALES}
    rng = np.random.RandomState(seed)
    history: List[RoundResult] = []
    n_stages = len(cfg.stage_sizes)
    box = {"params": params_full, "state": state_full}

    def select_fn(r, avail):
        return list(rng.choice(avail, size=min(clients_per_round, len(avail)),
                               replace=False))

    def train_fn(sel, r, sequential=None, faults=None):
        params_full, state_full = box["params"], box["state"]
        by_scale: Dict[float, List[int]] = {}
        for cid in sel:
            by_scale.setdefault(scale_of[cid], []).append(cid)
        # one fused dispatch per scale group, then overlapping-slice agg
        acc = jax.tree.map(lambda x: np.zeros(x.shape, np.float64), params_full)
        cnt = jax.tree.map(lambda x: np.zeros(x.shape, np.float64), params_full)
        acc_s = jax.tree.map(lambda x: np.zeros(x.shape, np.float64), state_full)
        cnt_s = jax.tree.map(lambda x: np.zeros(x.shape, np.float64), state_full)
        losses: Dict[int, float] = {}
        for sc, cids in by_scale.items():
            sub_shape, sub_state_shape = jax.eval_shape(
                lambda: models[sc].init(jax.random.PRNGKey(0)))
            sub = jax.tree.map(_slice_like, params_full, sub_shape)
            sub_st = jax.tree.map(_slice_like, state_full, sub_state_shape)
            f_g = ({c: k for c, k in faults.items() if c in cids}
                   if faults else None) or None
            p_g, s_g, l_g = engines[sc].run_round(clients_by_id, cids, sub,
                                                  sub_st, r,
                                                  sequential=sequential,
                                                  faults=f_g)
            W_g = float(sum(clients_by_id[c].num_samples for c in cids))
            losses.update(l_g)

            def add(a, c_, small):
                sl = tuple(slice(0, s) for s in small.shape)
                a[sl] += np.asarray(small, np.float64) * W_g
                c_[sl] += W_g

            jax.tree.map(add, acc, cnt, p_g)
            jax.tree.map(add, acc_s, cnt_s, s_g)

        def finalize(a, c_, full):
            out = np.asarray(full, np.float64).copy()
            mask = c_ > 0
            out[mask] = a[mask] / c_[mask]
            return jnp.asarray(out, full.dtype)

        box["params"] = jax.tree.map(finalize, acc, cnt, params_full)
        box["state"] = jax.tree.map(finalize, acc_s, cnt_s, state_full)
        return losses

    def on_round(rec):
        rr = RoundResult(rec.round_idx, n_stages - 1,
                         _mean_loss(rec.losses,
                                    prev=history[-1].loss if history else None),
                         selected=rec.selected, duration=rec.duration,
                         virtual_time=rec.t_end, dropped=rec.dropped)
        if eval_fn is not None and rec.round_idx % 10 == 0:
            rr.test_acc = eval_fn(model_full, box["params"], box["state"])
        history.append(rr)
        return False

    _run_loop(clients_by_id, select_fn, train_fn, on_round, rounds,
              aggregation=aggregation, time_model=time_model,
              availability=availability, faults=faults)
    return {"params": box["params"], "state": box["state"], "history": history,
            "participation": 1.0, "model": model_full}


# ---------------------------------------------------------------------------
# TiFL / Oort (selection-strategy baselines; full model required)
# ---------------------------------------------------------------------------


def run_tifl(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
             batch_size: int = 32, clients_per_round: int = 10,
             eval_fn=None, seed: int = 0, **kw) -> Dict:
    model = CNN(cfg)
    req = full_model_memory(model, batch_size)
    eligible = [c for c in clients if c.memory_bytes >= req]
    if not eligible:
        return {"inoperative": True, "participation": 0.0, "history": []}
    times = {c.client_id: c.num_samples / c.capability for c in eligible}
    qs = np.quantile(list(times.values()), [0.33, 0.66])
    tiers = {0: [], 1: [], 2: []}
    for c in eligible:
        t = times[c.client_id]
        tiers[0 if t <= qs[0] else (1 if t <= qs[1] else 2)].append(c.client_id)
    params, state = model.init(jax.random.PRNGKey(seed))
    clients_by_id = {c.client_id: c for c in eligible}

    def full_loss(p, frozen_unused, st, batch):
        return model.loss(p, st, batch, train=True)

    optimizer_fn = kw.pop("optimizer_fn", lambda: sgd(0.05))
    local_epochs = kw.pop("local_epochs", 1)
    fused = kw.pop("fused", True)
    compress_ratio = kw.pop("compress_ratio", None)
    compute_dtype = kw.pop("compute_dtype", None)
    aggregation = kw.pop("aggregation", "sync")
    time_model = kw.pop("time_model", None)
    availability = kw.pop("availability", None)
    screen_updates = kw.pop("screen_updates", False)
    aggregator = kw.pop("aggregator", "mean")
    faults = kw.pop("faults", None)
    if kw:
        raise TypeError(f"run_tifl: unknown kwargs {sorted(kw)}")
    # ONE engine reused across rounds (the seed rebuilt a jitted step per
    # round-scoped sub-server, recompiling every round)
    engine = RoundEngine(loss_fn=full_loss, optimizer=optimizer_fn(),
                         batch_size=batch_size, local_epochs=local_epochs,
                         fused=fused, compress_ratio=compress_ratio,
                         compute_dtype=compute_dtype,
                         screen=screen_updates, aggregator=aggregator)
    n_stages = len(cfg.stage_sizes)
    rng = np.random.RandomState(seed)
    history: List[RoundResult] = []
    box = {"params": params, "state": state}

    def select_fn(r, avail):
        # restrict each round to one tier (round-robin over non-empty tiers)
        avail_set = set(avail)
        live = [t for t in tiers.values() if t]
        tier = [c for c in live[r % len(live)] if c in avail_set]
        if not tier:
            return []
        return list(rng.choice(tier, size=min(clients_per_round, len(tier)),
                               replace=False))

    def train_fn(sel, r, sequential=None, faults=None):
        box["params"], box["state"], losses = engine.run_round(
            clients_by_id, sel, box["params"], box["state"], r,
            sequential=sequential, faults=faults)
        return losses

    def on_round(rec):
        rr = RoundResult(rec.round_idx, n_stages - 1,
                         _mean_loss(rec.losses,
                                    prev=history[-1].loss if history else None),
                         selected=rec.selected, duration=rec.duration,
                         virtual_time=rec.t_end, dropped=rec.dropped)
        if eval_fn is not None and rec.round_idx % 10 == 0:
            rr.test_acc = eval_fn(model, box["params"], box["state"])
        history.append(rr)
        return False

    time_model = (dataclasses.replace(time_model) if time_model is not None
                  else FleetTimeModel.from_clients(clients_by_id))
    time_model.payload_bytes = engine.per_client_uplink_bytes(params)
    _run_loop(clients_by_id, select_fn, train_fn, on_round, rounds,
              aggregation=aggregation, time_model=time_model,
              availability=availability, faults=faults)
    return {"params": box["params"], "state": box["state"], "history": history,
            "participation": len(eligible) / len(clients), "model": model}


def run_oort(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
             batch_size: int = 32, clients_per_round: int = 10,
             eval_fn=None, seed: int = 0, local_epochs: int = 1,
             fused: bool = True, compress_ratio=None, compute_dtype=None,
             aggregation="sync", time_model=None, availability=None,
             screen_updates: bool = False, aggregator: str = "mean",
             faults=None) -> Dict:
    from repro.core.selector.bandit import UtilBandit

    model = CNN(cfg)
    req = full_model_memory(model, batch_size)
    eligible = [c for c in clients if c.memory_bytes >= req]
    if not eligible:
        return {"inoperative": True, "participation": 0.0, "history": []}
    clients_by_id = {c.client_id: c for c in eligible}
    params, state = model.init(jax.random.PRNGKey(seed))
    bandit = UtilBandit(epsilon=0.3, seed=seed)

    def full_loss(p, frozen_unused, st, batch):
        return model.loss(p, st, batch, train=True)

    engine = RoundEngine(loss_fn=full_loss, optimizer=sgd(0.05),
                         batch_size=batch_size, local_epochs=local_epochs,
                         fused=fused, compress_ratio=compress_ratio,
                         compute_dtype=compute_dtype,
                         screen=screen_updates, aggregator=aggregator)
    history: List[RoundResult] = []
    n_stages = len(cfg.stage_sizes)
    box = {"params": params, "state": state}

    def select_fn(r, avail):
        return list(bandit.pick(avail, min(clients_per_round, len(avail))))

    def train_fn(sel, r, sequential=None, faults=None):
        box["params"], box["state"], losses = engine.run_round(
            clients_by_id, sel, box["params"], box["state"], r,
            sequential=sequential, faults=faults)
        for cid, loss_i in losses.items():
            if not np.isfinite(loss_i):
                continue  # screened/corrupted round must not poison utility
            c = clients_by_id[cid]
            # Oort stat util: |D_i| sqrt(mean loss^2) - time penalty
            t_i = c.num_samples / c.capability
            bandit.update(cid, c.num_samples * np.sqrt(loss_i ** 2) - 0.1 * t_i)
        bandit.next_round()
        return losses

    def on_round(rec):
        rr = RoundResult(rec.round_idx, n_stages - 1,
                         _mean_loss(rec.losses,
                                    prev=history[-1].loss if history else None),
                         selected=rec.selected, duration=rec.duration,
                         virtual_time=rec.t_end, dropped=rec.dropped)
        if eval_fn is not None and rec.round_idx % 10 == 0:
            rr.test_acc = eval_fn(model, box["params"], box["state"])
        history.append(rr)
        return False

    time_model = (dataclasses.replace(time_model) if time_model is not None
                  else FleetTimeModel.from_clients(clients_by_id))
    time_model.payload_bytes = engine.per_client_uplink_bytes(params)
    _run_loop(clients_by_id, select_fn, train_fn, on_round, rounds,
              aggregation=aggregation, time_model=time_model,
              availability=availability, faults=faults)
    return {"params": box["params"], "state": box["state"], "history": history,
            "participation": len(eligible) / len(clients), "model": model}
