"""The paper's six baselines (§V-A), implemented for the CNN testbed.

AllSmall     — width-scale the whole model to the minimum client memory.
ExclusiveFL  — vanilla FedAvg, only clients that fit the FULL model.
DepthFL      — depth-scaled submodels + auxiliary classifiers, per-stage agg.
HeteroFL     — per-client width scaling, overlapping-slice aggregation.
TiFL         — tier clients by round time, sample within a tier.
Oort         — utility-based selection (stat util x time penalty).

Each returns the same history format as the servers in fl/server.py so the
benchmark harness plots them together (paper Figs. 7-8 / Table I).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import freezing_cnn as fz
from repro.core.output_module import cnn_fc_only_apply, cnn_fc_only_init
from repro.fl.client import SimClient
from repro.fl.server import FedAvgServer, RoundResult, _weighted_avg, cnn_stage_memory_bytes
from repro.models.cnn import CNN, CNNConfig
from repro.models.module import PFac
from repro.optim import apply_updates, clip_by_global_norm, sgd


def full_model_memory(model: CNN, batch_size: int) -> float:
    n = len(model.cfg.stage_sizes)
    return sum(cnn_stage_memory_bytes(model, s, batch_size) for s in range(n))


def scaled_config(cfg: CNNConfig, scale: float) -> CNNConfig:
    chans = tuple(max(int(c * scale), 4) for c in cfg.stage_channels)
    return dataclasses.replace(cfg, stage_channels=chans,
                               name=f"{cfg.name}_x{scale:g}")


# ---------------------------------------------------------------------------
# AllSmall
# ---------------------------------------------------------------------------


def run_allsmall(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
                 batch_size: int = 32, eval_fn=None, seed: int = 0, **kw) -> Dict:
    """Scale channels until the model fits the SMALLEST client memory."""
    min_mem = min(c.memory_bytes for c in clients)
    scale = 1.0
    while scale > 0.05:
        model = CNN(scaled_config(cfg, scale))
        if full_model_memory(model, batch_size) <= min_mem:
            break
        scale *= 0.5
    model = CNN(scaled_config(cfg, scale))
    params, state = model.init(jax.random.PRNGKey(seed))
    srv = FedAvgServer(model, clients, batch_size=batch_size, seed=seed, **kw)
    out = srv.run(params, state, rounds=rounds,
                  eval_fn=(lambda p, s, st: eval_fn(model, p, s)) if eval_fn else None)
    out["scale"] = scale
    out["model"] = model
    return out


# ---------------------------------------------------------------------------
# ExclusiveFL
# ---------------------------------------------------------------------------


def run_exclusivefl(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
                    batch_size: int = 32, eval_fn=None, seed: int = 0, **kw) -> Dict:
    model = CNN(cfg)
    req = full_model_memory(model, batch_size)
    eligible = [c for c in clients if c.memory_bytes >= req]
    out: Dict = {"participation": len(eligible) / len(clients), "history": []}
    if not eligible:
        out["inoperative"] = True  # paper: ResNet18/VGG16 scenarios
        return out
    params, state = model.init(jax.random.PRNGKey(seed))
    srv = FedAvgServer(model, clients, batch_size=batch_size,
                       mem_required=req, seed=seed, **kw)
    res = srv.run(params, state, rounds=rounds,
                  eval_fn=(lambda p, s, st: eval_fn(model, p, s)) if eval_fn else None)
    res["participation"] = out["participation"]
    res["model"] = model
    return res


# ---------------------------------------------------------------------------
# DepthFL
# ---------------------------------------------------------------------------


def run_depthfl(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
                batch_size: int = 32, clients_per_round: int = 10,
                eval_fn=None, seed: int = 0, local_epochs: int = 1) -> Dict:
    """Depth-scaled submodels: client c trains stages [0..d_c) + aux head."""
    model = CNN(cfg)
    n_stages = len(cfg.stage_sizes)
    params, state = model.init(jax.random.PRNGKey(seed))
    # aux classifier per non-final depth
    fac = PFac(jax.random.PRNGKey(seed + 1), dtype=jnp.float32)
    aux = {d: cnn_fc_only_init(fac.sub(f"aux{d}"), cfg, d) for d in range(n_stages - 1)}

    # assign depth by memory
    depths = {}
    for c in clients:
        d = 0
        for s in range(n_stages):
            need = sum(cnn_stage_memory_bytes(model, t, batch_size) for t in range(s + 1))
            if c.memory_bytes >= need:
                d = s
        depths[c.client_id] = d
    participation = np.mean([depths[c.client_id] == n_stages - 1 for c in clients])

    def make_step(depth: int):
        def loss_fn(p, st, batch):
            h = batch["x"]
            if cfg.kind == "resnet":
                h, st = model.stem(p, st, h, train=True)
            h, st = model.run_stages(p, st, h, 0, depth + 1, train=True)
            logits = model.head(p, h) if depth == n_stages - 1 \
                else cnn_fc_only_apply(p["aux"], h)
            lf = logits.astype(jnp.float32)
            logz = jax.scipy.special.logsumexp(lf, axis=-1)
            gold = jnp.take_along_axis(lf, batch["y"][:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold), st

        opt = sgd(0.05)

        @jax.jit
        def step(p, frozen_unused, st, opt_state, batch):
            (loss, new_st), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, st, batch)
            grads, _ = clip_by_global_norm(grads, 10.0)
            ups, opt_state = opt.update(grads, opt_state, p)
            return apply_updates(p, ups), new_st, opt_state, loss

        return step, opt

    steps = {d: make_step(d) for d in range(n_stages)}
    rng = np.random.RandomState(seed)
    history = []
    for r in range(rounds):
        sel = list(rng.choice([c.client_id for c in clients],
                              size=min(clients_per_round, len(clients)), replace=False))
        updates, weights, losses = [], [], []
        for cid in sel:
            c = next(cl for cl in clients if cl.client_id == cid)
            d = depths[cid]
            sub = {k: params[k] for k in params if k != "fc"}
            if d == n_stages - 1:
                sub["fc"] = params["fc"]
            else:
                sub = dict(sub)
                sub["aux"] = aux[d]
            step, opt = steps[d]
            p_i, s_i, loss_i, _ = c.local_train(step, sub, None, state,
                                                opt.init(sub),
                                                batch_size=batch_size,
                                                epochs=local_epochs, round_idx=r)
            updates.append((cid, d, p_i, s_i))
            weights.append(c.num_samples)
            losses.append(loss_i)
        # per-stage aggregation over clients that trained the stage
        w = np.asarray(weights, np.float64)
        new_params = dict(params)
        for s in range(n_stages):
            having = [(i, u) for i, u in enumerate(updates) if u[1] >= s]
            if not having:
                continue
            ws = np.asarray([w[i] for i, _ in having])
            ws /= ws.sum()
            new_params["stages"] = dict(new_params["stages"])
            new_params["stages"][f"stage{s}"] = _weighted_avg(
                [u[2]["stages"][f"stage{s}"] for _, u in having], ws)
        if cfg.kind == "resnet":
            ws = w / w.sum()
            new_params["stem"] = _weighted_avg([u[2]["stem"] for u in updates], ws)
        fc_have = [(i, u) for i, u in enumerate(updates) if u[1] == n_stages - 1]
        if fc_have:
            ws = np.asarray([w[i] for i, _ in fc_have])
            ws /= ws.sum()
            new_params["fc"] = _weighted_avg([u[2]["fc"] for _, u in fc_have], ws)
        for d in range(n_stages - 1):
            have = [(i, u) for i, u in enumerate(updates) if u[1] == d]
            if have:
                ws = np.asarray([w[i] for i, _ in have])
                ws /= ws.sum()
                aux[d] = _weighted_avg([u[2]["aux"] for _, u in have], ws)
        params = new_params
        state = _weighted_avg([u[3] for u in updates], w / w.sum())
        rr = RoundResult(r, n_stages - 1, float(np.mean(losses)), selected=sel)
        if eval_fn is not None and r % 10 == 0:
            rr.test_acc = eval_fn(model, params, state)
        history.append(rr)
    return {"params": params, "state": state, "history": history,
            "participation": float(participation), "model": model}


# ---------------------------------------------------------------------------
# HeteroFL
# ---------------------------------------------------------------------------


_HFL_SCALES = (1.0, 0.5, 0.25, 0.125)


def _slice_like(full, small):
    """Upper-left slice of `full` with `small`'s shape."""
    slices = tuple(slice(0, s) for s in small.shape)
    return full[slices]


def run_heterofl(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
                 batch_size: int = 32, clients_per_round: int = 10,
                 eval_fn=None, seed: int = 0, local_epochs: int = 1) -> Dict:
    model_full = CNN(cfg)
    params_full, state_full = model_full.init(jax.random.PRNGKey(seed))
    # assign the largest scale whose model fits each client
    scale_of = {}
    models = {s: CNN(scaled_config(cfg, s)) for s in _HFL_SCALES}
    for c in clients:
        sc = _HFL_SCALES[-1]
        for s in _HFL_SCALES:
            if full_model_memory(models[s], batch_size) <= c.memory_bytes:
                sc = s
                break
        scale_of[c.client_id] = sc

    def make_step(scale):
        model_s = models[scale]
        opt = sgd(0.05)

        @jax.jit
        def step(p, frozen_unused, st, opt_state, batch):
            def loss_fn(p_, st_):
                return model_s.loss(p_, st_, batch, train=True)

            (loss, new_st), grads = jax.value_and_grad(
                lambda p_: loss_fn(p_, st), has_aux=True)(p)
            grads, _ = clip_by_global_norm(grads, 10.0)
            ups, opt_state2 = opt.update(grads, opt_state, p)
            return apply_updates(p, ups), new_st, opt_state2, loss

        return step, opt

    steps = {s: make_step(s) for s in _HFL_SCALES}
    rng = np.random.RandomState(seed)
    history = []
    n_stages = len(cfg.stage_sizes)
    for r in range(rounds):
        sel = list(rng.choice([c.client_id for c in clients],
                              size=min(clients_per_round, len(clients)), replace=False))
        # slice out submodels
        updates, weights = [], []
        losses = []
        for cid in sel:
            c = next(cl for cl in clients if cl.client_id == cid)
            sc = scale_of[cid]
            sub_shape, sub_state_shape = jax.eval_shape(
                lambda: models[sc].init(jax.random.PRNGKey(0)))
            sub = jax.tree.map(_slice_like, params_full, sub_shape)
            sub_st = jax.tree.map(_slice_like, state_full, sub_state_shape)
            step, opt = steps[sc]
            p_i, s_i, loss_i, _ = c.local_train(step, sub, None, sub_st,
                                                opt.init(sub),
                                                batch_size=batch_size,
                                                epochs=local_epochs, round_idx=r)
            updates.append((p_i, s_i))
            weights.append(c.num_samples)
            losses.append(loss_i)
        # overlapping-slice aggregation into the full model
        acc = jax.tree.map(lambda x: np.zeros(x.shape, np.float64), params_full)
        cnt = jax.tree.map(lambda x: np.zeros(x.shape, np.float64), params_full)
        acc_s = jax.tree.map(lambda x: np.zeros(x.shape, np.float64), state_full)
        cnt_s = jax.tree.map(lambda x: np.zeros(x.shape, np.float64), state_full)
        for (p_i, s_i), wi in zip(updates, weights):
            def add(a, c_, small):
                sl = tuple(slice(0, s) for s in small.shape)
                a[sl] += np.asarray(small, np.float64) * wi
                c_[sl] += wi

            jax.tree.map(add, acc, cnt, p_i)
            jax.tree.map(add, acc_s, cnt_s, s_i)

        def finalize(a, c_, full):
            out = np.asarray(full, np.float64).copy()
            mask = c_ > 0
            out[mask] = a[mask] / c_[mask]
            return jnp.asarray(out, full.dtype)

        params_full = jax.tree.map(finalize, acc, cnt, params_full)
        state_full = jax.tree.map(finalize, acc_s, cnt_s, state_full)
        rr = RoundResult(r, n_stages - 1, float(np.mean(losses)), selected=sel)
        if eval_fn is not None and r % 10 == 0:
            rr.test_acc = eval_fn(model_full, params_full, state_full)
        history.append(rr)
    return {"params": params_full, "state": state_full, "history": history,
            "participation": 1.0, "model": model_full}


# ---------------------------------------------------------------------------
# TiFL / Oort (selection-strategy baselines; full model required)
# ---------------------------------------------------------------------------


def run_tifl(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
             batch_size: int = 32, clients_per_round: int = 10,
             eval_fn=None, seed: int = 0, **kw) -> Dict:
    model = CNN(cfg)
    req = full_model_memory(model, batch_size)
    eligible = [c for c in clients if c.memory_bytes >= req]
    if not eligible:
        return {"inoperative": True, "participation": 0.0, "history": []}
    times = {c.client_id: c.num_samples / c.capability for c in eligible}
    qs = np.quantile(list(times.values()), [0.33, 0.66])
    tiers = {0: [], 1: [], 2: []}
    for c in eligible:
        t = times[c.client_id]
        tiers[0 if t <= qs[0] else (1 if t <= qs[1] else 2)].append(c.client_id)
    rng = np.random.RandomState(seed)
    params, state = model.init(jax.random.PRNGKey(seed))
    srv = FedAvgServer(model, eligible, batch_size=batch_size, seed=seed, **kw)
    # monkey-select: restrict each round to one tier
    history = []
    for r in range(rounds):
        tier = [t for t in tiers.values() if t][r % sum(1 for t in tiers.values() if t)]
        sel_clients = [c for c in eligible if c.client_id in tier]
        sub = FedAvgServer(model, sel_clients, batch_size=batch_size,
                           clients_per_round=min(clients_per_round, len(sel_clients)),
                           seed=seed + r)
        res = sub.run(params, state, rounds=1,
                      eval_fn=(lambda p, s, st: eval_fn(model, p, s))
                      if (eval_fn and r % 10 == 0) else None)
        params, state = res["params"], res["state"]
        rr = res["history"][0]
        rr.round_idx = r
        history.append(rr)
    return {"params": params, "state": state, "history": history,
            "participation": len(eligible) / len(clients), "model": model}


def run_oort(cfg: CNNConfig, clients: List[SimClient], *, rounds: int,
             batch_size: int = 32, clients_per_round: int = 10,
             eval_fn=None, seed: int = 0, local_epochs: int = 1) -> Dict:
    from repro.core.selector.bandit import UtilBandit

    model = CNN(cfg)
    req = full_model_memory(model, batch_size)
    eligible = [c for c in clients if c.memory_bytes >= req]
    if not eligible:
        return {"inoperative": True, "participation": 0.0, "history": []}
    params, state = model.init(jax.random.PRNGKey(seed))
    bandit = UtilBandit(epsilon=0.3, seed=seed)
    opt = sgd(0.05)

    def full_loss(p, st, batch):
        return model.loss(p, st, batch, train=True)

    @jax.jit
    def step_fn(p, frozen_unused, st, opt_state, batch):
        (loss, new_st), grads = jax.value_and_grad(full_loss, has_aux=True)(p, st, batch)
        grads, _ = clip_by_global_norm(grads, 10.0)
        ups, opt_state = opt.update(grads, opt_state, p)
        return apply_updates(p, ups), new_st, opt_state, loss

    history = []
    n_stages = len(cfg.stage_sizes)
    for r in range(rounds):
        sel = bandit.pick([c.client_id for c in eligible],
                          min(clients_per_round, len(eligible)))
        updates, weights, losses = [], [], []
        for cid in sel:
            c = next(cl for cl in eligible if cl.client_id == cid)
            p_i, s_i, loss_i, _ = c.local_train(step_fn, params, None, state,
                                                opt.init(params),
                                                batch_size=batch_size,
                                                epochs=local_epochs, round_idx=r)
            updates.append((p_i, s_i))
            weights.append(c.num_samples)
            losses.append(loss_i)
            # Oort stat util: |D_i| sqrt(mean loss^2) - time penalty
            t_i = c.num_samples / c.capability
            bandit.update(cid, c.num_samples * np.sqrt(loss_i ** 2) - 0.1 * t_i)
        bandit.next_round()
        w = np.asarray(weights, np.float64)
        w /= w.sum()
        params = _weighted_avg([u[0] for u in updates], w)
        state = _weighted_avg([u[1] for u in updates], w)
        rr = RoundResult(r, n_stages - 1, float(np.mean(losses)), selected=list(sel))
        if eval_fn is not None and r % 10 == 0:
            rr.test_acc = eval_fn(model, params, state)
        history.append(rr)
    return {"params": params, "state": state, "history": history,
            "participation": len(eligible) / len(clients), "model": model}
