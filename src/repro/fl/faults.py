"""Deterministic fault injection for federated rounds (ISSUE 7 tentpole #1).

Real heterogeneous fleets fail constantly — flaky edge devices corrupt
updates mid-computation, crash between local training and upload, or hang
with an update half-uploaded (ProFL arXiv:2404.13349, NeuLite
arXiv:2408.10826 both motivate progressive training for exactly these
devices). The simulator (fl/sim.py) already models *absence* (availability
and mid-round dropout) but had no way to inject *corrupted computation*.

``FaultInjector`` draws one deterministic fault decision per
(seed, round, client) via the same splitmix64-style integer hash discipline
as ``AvailabilityTrace``: draws are independent of cohort iteration order
and of which other clients are queried, so fault schedules are
permutation-invariant and replay bit-identically across checkpoint/resume.

Fault kinds:

  ``"nan"`` / ``"inf"``   the client's update delta is fully non-finite
                          (emulates NaN/Inf gradients poisoning local
                          training); the reported loss goes NaN too
  ``"signflip"``          delta negated — a directed (norm-preserving)
                          corruption that finite/norm screening cannot see;
                          the robust aggregators (engine.py
                          ``aggregator="trimmed_mean"|"coord_median"``) are
                          the defense
  ``"amplify"``           delta scaled by ``amplify`` (default 50x) —
                          caught by the median delta-norm outlier mask
  ``"crash"``             mid-round crash: compute time is spent, the
                          update never reaches the server (handled by the
                          aggregation policies, not the engine)
  ``"hang"``              an in-flight async client never completes;
                          recoverable only via
                          ``AsyncBufferedAggregation(timeout_s=...)``

The first four ("corruption" kinds) flow through the round engine — either
as an in-graph ``fault_codes`` vector on the fused dispatch or applied
host-side on the sequential path — so corrupted updates hit the in-graph
screening mask exactly like a real byzantine update would.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FaultInjector", "FAULT_KINDS", "CORRUPT_KINDS", "FAULT_CODE",
           "hash_draws", "apply_fault_to_update"]

#: every kind the injector can draw
FAULT_KINDS: Tuple[str, ...] = ("nan", "inf", "signflip", "amplify",
                                "crash", "hang")
#: kinds that corrupt the *content* of an update (engine-visible)
CORRUPT_KINDS: Tuple[str, ...] = ("nan", "inf", "signflip", "amplify")
#: in-graph integer codes for the corruption kinds (0 = no fault)
FAULT_CODE: Dict[str, int] = {"nan": 1, "inf": 2, "signflip": 3,
                              "amplify": 4}


def hash_draws(seed: int, round_idx: int, ids: Sequence[int]) -> np.ndarray:
    """One deterministic uniform per (seed, round, client), vectorized via a
    splitmix64-style integer hash — independent of cohort order and of
    which other clients are queried (so schedules stay
    permutation-invariant and traces replay across resume), and O(N) array
    work rather than per-client RandomState construction. Canonical copy of
    the availability-trace hash (fl/sim.py aliases it)."""
    c1 = np.uint64(0x9E3779B97F4A7C15)
    c2 = np.uint64(0xBF58476D1CE4E5B9)
    c3 = np.uint64(0x94D049BB133111EB)
    with np.errstate(over="ignore"):   # uint64 wraparound is the hash
        x = (np.asarray(ids, np.uint64) * c1
             + np.uint64(round_idx % (1 << 63)) * c2
             + np.uint64(seed % (1 << 63)) * c3)
        x ^= x >> np.uint64(30)
        x *= c2
        x ^= x >> np.uint64(27)
        x *= c3
        x ^= x >> np.uint64(31)
    return (x >> np.uint64(11)).astype(np.float64) / float(1 << 53)


@dataclass
class FaultInjector:
    """Seeded per-(client, round) fault schedule.

    ``p_fault`` gates whether a client faults this round; a second,
    independent draw picks the kind uniformly from ``kinds``. Draws are
    keyed per (seed, round, client) only — querying a cohort subset, a
    permutation, or one client at a time yields the same per-client
    verdicts (property-tested).

    ``start_round`` delays injection (faults only fire at
    ``round_idx >= start_round``) — useful for poisoning specifically the
    post-freeze window in rollback tests and benchmarks.
    """

    p_fault: float = 0.0
    kinds: Tuple[str, ...] = ("nan", "amplify", "crash")
    amplify: float = 50.0
    seed: int = 0
    start_round: int = 0

    def __post_init__(self):
        self.kinds = tuple(self.kinds)
        unknown = [k for k in self.kinds if k not in FAULT_KINDS]
        if unknown:
            raise ValueError(f"unknown fault kinds {unknown}; "
                             f"choose from {FAULT_KINDS}")

    def fault_for(self, cid: int, round_idx: int) -> Optional[str]:
        """This client's fault kind this round (None = healthy)."""
        return self.schedule([cid], round_idx).get(int(cid))

    def schedule(self, ids: Sequence[int], round_idx: int) -> Dict[int, str]:
        """{client_id: kind} for the faulty subset of ``ids`` this round."""
        ids = list(ids)
        if (self.p_fault <= 0.0 or not ids
                or round_idx < self.start_round or not self.kinds):
            return {}
        gate = hash_draws(self.seed + 0x5AFE, round_idx, ids)
        pick = hash_draws(self.seed + 0xFA11, round_idx, ids)
        out: Dict[int, str] = {}
        for cid, g, u in zip(ids, gate, pick):
            if g < self.p_fault:
                out[int(cid)] = self.kinds[
                    min(int(u * len(self.kinds)), len(self.kinds) - 1)]
        return out

    def corrupt_codes(self, faults: Optional[Dict[int, str]],
                      cids: Sequence[int]) -> Optional[np.ndarray]:
        """[K] int32 in-graph code vector for a cohort (None when the
        cohort is clean) — the fused dispatch's ``fault_codes`` input."""
        return corrupt_codes(faults, cids)


def corrupt_codes(faults: Optional[Dict[int, str]],
                  cids: Sequence[int]) -> Optional[np.ndarray]:
    """{cid: kind} -> [K] int32 codes aligned with ``cids`` (0 = clean);
    None when no client in the cohort carries a corruption kind."""
    if not faults:
        return None
    codes = np.asarray([FAULT_CODE.get(faults.get(int(c), ""), 0)
                        for c in cids], np.int32)
    return codes if codes.any() else None


def apply_fault_to_update(kind: str, params, p_i, *, amplify: float = 50.0):
    """Host-side corruption of one client's trained params (sequential
    path): same delta-space semantics as the in-graph ``fault_codes``
    transform in ``fl/engine.py`` — delta = p_i - params is NaN'd / Inf'd /
    negated / scaled, then re-added to the round's start params."""
    if kind not in CORRUPT_KINDS:
        raise ValueError(f"not a corruption kind: {kind!r}")

    def leaf(p0, pk):
        p0f = p0.astype(jnp.float32)
        d = pk.astype(jnp.float32) - p0f
        if kind == "nan":
            d = jnp.full_like(d, jnp.nan)
        elif kind == "inf":
            d = jnp.full_like(d, jnp.inf)
        elif kind == "signflip":
            d = -d
        else:  # amplify
            d = d * jnp.float32(amplify)
        return (p0f + d).astype(pk.dtype)

    return jax.tree.map(leaf, params, p_i)
