"""Virtual-time federated simulation core: ONE event-driven loop under every
orchestration policy.

The paper's headline claims are time-domain (up to 2.02x faster under system
heterogeneity, Eqs. 5-7 + deadline-based straggler handling), but the seed
orchestration hand-rolled a synchronous Python round loop per trainer and
could not express time at all. This module owns the missing substrate:

  * ``FleetTimeModel`` — the vectorized, device-resident Eq. 5-7 kernel
    (``core/time_model.py``) over per-client arrays: stage compute times,
    heterogeneous uplink rates applied to the round's payload bytes, and a
    deterministic per-(client, round) lognormal jitter. Deterministic means
    the virtual-time trajectory replays bit-identically across
    checkpoint/resume.
  * ``AvailabilityTrace`` — seeded per-(client, round) availability and
    mid-round dropout draws; an all-dropped cohort costs 0.0 virtual
    seconds (``core.time_model.round_time``'s empty-cohort branch).
  * Aggregation policies behind one ``tick`` interface:
      - ``SyncAggregation``     Eq. 7 barrier: the round lasts as long as
                                its slowest surviving client.
      - ``DeadlineAggregation`` the paper's partial aggregation: clients
                                finishing after T_dl are dropped and the
                                surviving cohort is aggregated by the same
                                (masked) Eq. 1 inside the fused engine
                                dispatch. Mirrors the seed server's
                                median-relative deadline semantics exactly.
      - ``AsyncBufferedAggregation``  FedBuff-style buffered async: clients
                                train on the params version at dispatch
                                time; the server merges every
                                ``buffer_size`` completions with
                                staleness-discounted Eq. 1 weights.
  * ``FederatedLoop`` — replays selection -> local training -> aggregation
    -> observation per virtual tick. ``SmartFreezeServer``, ``FedAvgServer``
    and all six baselines are thin hook bundles over this one loop; none of
    them owns a round loop anymore.
  * Checkpoint/resume plumbing (``pack_rng_state``, ``selector_state_tree``)
    so pace-controller windows, selector/bandit streams, EF residual pools
    and the virtual clock all serialize through ``CheckpointManager``.

Policies drive the loop (not vice versa) because their tick shapes differ:
sync/deadline run one cohort per tick; async-buffered keeps an in-flight
heap across ticks and a tick is one *aggregation event*. Everything the
policies need from the host trainer is narrowed to the ``FederatedLoop``
hook surface, which is what lets seven formerly-duplicated loops share one
engine-backed implementation.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.time_model import (cohort_round_time, completion_jitter,
                                   completion_times_vec, stage_times_vec,
                                   uplink_times_vec)
from repro.fl.faults import (CORRUPT_KINDS, FaultInjector,
                             apply_fault_to_update, hash_draws)


# ---------------------------------------------------------------------------
# Fleet time model (vectorized Eqs. 5-7 + links + jitter)
# ---------------------------------------------------------------------------


@dataclass
class FleetTimeModel:
    """Per-client round completion times, device-resident.

    ``compute_s[i]`` is client i's base local-training time for the current
    (sub)model — Eq. 6 with whatever FLOPs estimate the caller used
    (``from_clients`` defaults to the selection heuristic
    ``|D_i| / c_i``, which is what keeps refactored synchronous
    trajectories identical to the seed servers'). ``link_rate[i]`` is the
    uplink in bytes/s (``inf`` = free network, uplink time 0); the payload
    is set per stage/round by the server via ``payload_bytes``.
    """

    client_ids: np.ndarray                 # [N] external ids
    compute_s: jnp.ndarray                 # [N] f32 seconds
    link_rate: jnp.ndarray                 # [N] f32 bytes/s (inf ok)
    jitter: float = 0.0                    # lognormal sigma (0 = off)
    seed: int = 0
    payload_bytes: float = 0.0             # per-client uplink payload
    compute_scale: Optional[jnp.ndarray] = None  # [N] f32 (None = ones)
    _row: Dict[int, int] = field(default_factory=dict, repr=False)

    def __post_init__(self):
        self.client_ids = np.asarray(self.client_ids)
        self.compute_s = jnp.asarray(self.compute_s, jnp.float32)
        self.link_rate = jnp.asarray(self.link_rate, jnp.float32)
        if self.compute_scale is not None:
            self.compute_scale = jnp.asarray(self.compute_scale, jnp.float32)
        self._row = {int(c): i for i, c in enumerate(self.client_ids)}

    def with_compute_scale(self, scale_of: Dict[int, float]
                           ) -> "FleetTimeModel":
        """Copy with per-client compute-time multipliers (1.0 elsewhere) —
        how feature-cache tier admission reaches the virtual clock: a
        cached client's local step drops the frozen-prefix forward
        (``core.time_model.cnn_cached_compute_scale`` /
        ``lm_cached_compute_scale``), so rounds shorten and deadline
        cohorts change with who got admitted."""
        scale = np.asarray(self.compute_scale, np.float32).copy() \
            if self.compute_scale is not None \
            else np.ones(len(self.client_ids), np.float32)
        for cid, s in scale_of.items():
            scale[self._row[int(cid)]] = float(s)
        import dataclasses as _dc
        return _dc.replace(self, compute_scale=scale)

    def shard(self, mesh) -> "FleetTimeModel":
        """Copy with the per-client columns placed along ``mesh``'s client
        axis (replicated when N does not divide the axis size — the
        ``make_rules`` divisibility fallback), so the Eq. 5-7 time kernel,
        selection, and the fused round share one placement."""
        from repro.dist.sharding import shard_client_arrays
        import dataclasses as _dc
        cols = shard_client_arrays(mesh, (self.compute_s, self.link_rate,
                                          self.compute_scale))
        return _dc.replace(self, compute_s=cols[0], link_rate=cols[1],
                           compute_scale=cols[2])

    @classmethod
    def from_clients(cls, clients, *, flops_per_sample: float = 1.0,
                     rho: float = 1.0, link_rates=None, jitter: float = 0.0,
                     seed: int = 0) -> "FleetTimeModel":
        """Build from a ``SimClient`` fleet (list or id-keyed dict).

        With the defaults (``flops_per_sample=rho=1``, no links, no jitter)
        the per-client time is ``num_samples / capability`` — exactly the
        seed servers' straggler heuristic, so sync/deadline trajectories
        are unchanged by routing through the time model.

        ``link_rates`` aligns with the *given* client order (list) or is an
        id-keyed dict; rows are stored sorted by client id internally."""
        cs = list(clients.values()) if isinstance(clients, dict) else list(clients)
        if link_rates is None:
            rate_of = {c.client_id: getattr(c, "link_rate", np.inf)
                       for c in cs}
        elif isinstance(link_rates, dict):
            rate_of = dict(link_rates)
        else:
            if len(link_rates) != len(cs):
                raise ValueError(f"link_rates has {len(link_rates)} entries "
                                 f"for {len(cs)} clients")
            rate_of = {c.client_id: r for c, r in zip(cs, link_rates)}
        cs = sorted(cs, key=lambda c: c.client_id)
        ids = np.asarray([c.client_id for c in cs])
        n = np.asarray([c.num_samples for c in cs], np.float32)
        cap = np.asarray([c.capability for c in cs], np.float32)
        compute = np.asarray(stage_times_vec(
            jnp.float32(flops_per_sample), jnp.asarray(n), jnp.asarray(cap),
            jnp.float32(rho)))
        return cls(client_ids=ids, compute_s=compute,
                   link_rate=np.asarray([rate_of[c.client_id] for c in cs],
                                        np.float32),
                   jitter=jitter, seed=seed)

    # ----- queries -----

    def population_times(self, round_idx: int) -> jnp.ndarray:
        """[N] completion times for the whole fleet — the jitted hot path
        (one fused kernel over resident arrays; used by the sim_scale
        benchmark and population-scale schedulers)."""
        jit = jnp.asarray(completion_jitter(len(self.client_ids), self.seed,
                                            round_idx, self.jitter))
        up = uplink_times_vec(jnp.float32(self.payload_bytes), self.link_rate)
        compute = (self.compute_s if self.compute_scale is None
                   else self.compute_s * self.compute_scale)
        return completion_times_vec(compute, up, jit)

    def cohort_times(self, cohort: Sequence[int], round_idx: int
                     ) -> Dict[int, float]:
        """Completion time per selected client id."""
        if not len(cohort):
            return {}
        t = np.asarray(self.population_times(round_idx))
        return {int(c): float(t[self._row[int(c)]]) for c in cohort}


# ---------------------------------------------------------------------------
# Availability / dropout traces
# ---------------------------------------------------------------------------


# One deterministic uniform per (seed, round, client). The canonical
# splitmix64 implementation moved to fl/faults.py (ISSUE 7) so the fault
# injector shares the exact draw discipline; same values as before.
_draws = hash_draws


@dataclass
class AvailabilityTrace:
    """Jittered client availability + mid-round dropout.

    ``p_available`` gates whether a client can be *selected* this round;
    ``p_dropout`` kills a selected client mid-round (its update never
    reaches the server; sync waits only for survivors, deadline counts it
    as missing T_dl). Both draws are seeded per (client, round), so traces
    replay identically across checkpoint/resume."""

    p_available: float = 1.0
    p_dropout: float = 0.0
    seed: int = 0

    def available(self, ids: Sequence[int], round_idx: int) -> List[int]:
        ids = list(ids)
        if self.p_available >= 1.0 or not ids:
            return ids
        u = _draws(self.seed, round_idx, ids)
        return [c for c, ui in zip(ids, u) if ui < self.p_available]

    def dropouts(self, cohort: Sequence[int], round_idx: int) -> List[int]:
        cohort = list(cohort)
        if self.p_dropout <= 0.0 or not cohort:
            return []
        u = _draws(self.seed + 1, round_idx, cohort)
        return [c for c, ui in zip(cohort, u) if ui < self.p_dropout]


# ---------------------------------------------------------------------------
# Tick records
# ---------------------------------------------------------------------------


@dataclass
class RoundRecord:
    """What one virtual tick did — the loop's policy-agnostic history row."""
    round_idx: int
    selected: List[int]                    # clients whose updates aggregated
    losses: Dict[int, float]
    dropped: List[int] = field(default_factory=list)   # deadline/dropout
    t_start: float = 0.0
    duration: float = 0.0
    t_end: float = 0.0
    policy: str = "sync"
    sequential: bool = False
    staleness: Dict[int, int] = field(default_factory=dict)  # async only
    faults: Dict[int, str] = field(default_factory=dict)     # injected kinds
    retries: Dict[int, int] = field(default_factory=dict)    # async re-dispatch


# ---------------------------------------------------------------------------
# Aggregation policies
# ---------------------------------------------------------------------------


class SyncAggregation:
    """Eq. 7 barrier: everyone selected trains; the round lasts as long as
    the slowest *surviving* client. Dropped clients' updates never arrive
    and the simulator charges no extra wait for discovering they are gone
    (an optimistic server model — failure-detection latency is not
    simulated). Injected crash/hang faults lose the client's update but
    still charge its compute time to the barrier (under a barrier a hang is
    a crash the server times out on); corruption kinds flow through to the
    trainer hook and are defended (or not) by the round engine."""

    name = "sync"

    def tick(self, loop: "FederatedLoop", r: int) -> RoundRecord:
        avail = loop.available(r)
        sel = loop.select_fn(r, avail) if avail else []
        dropped = loop.dropouts(sel, r)
        cohort = [c for c in sel if c not in set(dropped)]
        times = loop.times(sel, r)
        sched = loop.fault_schedule(cohort, r)
        losses, crashed = loop.run_train(cohort, r, schedule=sched)
        survivors = [c for c in cohort if c not in set(crashed)]
        # crashed clients spent their compute: the barrier waited on them
        dur = cohort_round_time([times[c] for c in cohort])
        return RoundRecord(r, survivors, losses, dropped=dropped + crashed,
                           t_start=loop.clock, duration=dur,
                           t_end=loop.clock + dur, policy=self.name,
                           faults=dict(sched))


@dataclass
class DeadlineAggregation:
    """Paper §IV-C straggler mitigation: partial aggregation over clients
    that finish before T_dl; the surviving cohort goes through the same
    Eq. 1 aggregation (in-graph for the fused engine — dropping a client
    IS the mask). Semantics mirror the seed ``SmartFreezeServer`` exactly:
    a relative deadline ``factor * median(times)`` considered only when the
    cohort is larger than 2, and the trim only applied when at least
    ``max(min_keep, len(cohort) // 2)`` clients survive; straggler rounds
    run the engine's sequential escape hatch (``sequential=True``) like the
    seed did. ``deadline_s`` switches to an absolute per-round deadline."""

    factor: float = 2.0
    deadline_s: Optional[float] = None
    min_keep: int = 2
    name: str = "deadline"
    sequential: bool = True

    def tick(self, loop: "FederatedLoop", r: int) -> RoundRecord:
        avail = loop.available(r)
        sel = loop.select_fn(r, avail) if avail else []
        times = loop.times(sel, r)
        kept, straggler_round = list(sel), False
        deadline = self.deadline_s
        if deadline is not None and sel:
            # absolute per-round deadline: applies to any cohort size, and
            # the server aggregates whoever made it (possibly nobody)
            straggler_round = True
            kept = [c for c in sel if times[c] <= deadline]
        elif len(sel) > 2:
            straggler_round = True
            deadline = float(np.median([times[c] for c in sel])) * self.factor
            finishers = [c for c in sel if times[c] <= deadline]
            if len(finishers) >= max(self.min_keep, len(sel) // 2):
                kept = finishers
        dropped = loop.dropouts(kept, r)
        cohort = [c for c in kept if c not in set(dropped)]
        seq = True if (straggler_round and self.sequential) else None
        sched = loop.fault_schedule(cohort, r)
        losses, crashed = loop.run_train(cohort, r, schedule=sched,
                                         sequential=seq)
        survivors = [c for c in cohort if c not in set(crashed)]
        late = [c for c in sel if c not in set(kept)]
        if late:  # server waited until the deadline before aggregating
            dur = float(deadline)
        else:
            # crashed clients spent their compute before failing
            dur = cohort_round_time([times[c] for c in cohort])
        return RoundRecord(r, survivors, losses,
                           dropped=late + dropped + crashed,
                           t_start=loop.clock, duration=dur,
                           t_end=loop.clock + dur, policy=self.name,
                           sequential=bool(seq), faults=dict(sched))


@dataclass
class AsyncBufferedAggregation:
    """FedBuff-style buffered asynchronous aggregation (staleness-weighted).

    The server keeps up to ``concurrency`` clients in flight, each training
    from the params *version* it was dispatched at. One tick = one
    aggregation event: pop completions (virtual-time order) until
    ``buffer_size`` updates are buffered, then apply

        params += sum_i w_i * (theta_i - theta_{dispatch(i)}) / sum_i w_i,
        w_i = |D_i| * (1 + staleness_i) ** -staleness_power

    and bump the version. Clients still in flight keep their (now stale)
    base version — that is where real staleness comes from. Requires the
    loop's ``snapshot_fn`` / ``train_one_fn`` / ``get_model_fn`` /
    ``set_model_fn`` hooks (the engine-backed servers provide them;
    submodel baselines don't and raise).

    Checkpoint note: the in-flight heap (which holds per-dispatch param
    snapshots) is deliberately NOT serialized — a resumed async run
    re-dispatches from the restored model/clock, so the bit-identical
    resume guarantee applies to the sync and deadline policies.

    Fault tolerance (ISSUE 7): ``timeout_s`` arms a virtual-clock watchdog
    per dispatch — an in-flight client whose completion has not landed by
    ``t_dispatch + timeout_s * retry_backoff**attempt`` is abandoned and
    re-dispatched from the CURRENT model (up to ``max_retries`` attempts,
    exponential backoff on the watchdog), so an injected ``"hang"`` (a
    completion that never arrives) can no longer stall a buffer slot
    forever. Without a timeout a hung entry is parked: the pop loop skips
    non-finite completion times and the tick returns short — the documented
    starvation mode the watchdog exists to fix. ``"crash"`` spends the
    client's compute and arrives as a loss-less failure (no merge);
    corruption kinds are applied to the completed update host-side and a
    non-finite screen at merge time (when the loop's injector is armed)
    drops them instead of folding NaN into the running model."""

    buffer_size: int = 4
    concurrency: int = 8
    staleness_power: float = 0.5
    timeout_s: Optional[float] = None
    max_retries: int = 2
    retry_backoff: float = 2.0
    name: str = "async"

    def tick(self, loop: "FederatedLoop", r: int) -> RoundRecord:
        if loop.train_one_fn is None or loop.set_model_fn is None:
            raise ValueError(f"{self.name} aggregation needs the loop's "
                             "snapshot/train_one/get_model/set_model hooks")
        st = loop.async_state
        t0 = loop.clock
        self._refill(loop, r, t0)
        merged: List[Tuple] = []
        completed: List[int] = []
        losses: Dict[int, float] = {}
        staleness: Dict[int, int] = {}
        dropped: List[int] = []
        faulted: Dict[int, str] = {}
        retries: Dict[int, int] = {}
        clock = t0
        # bounded event budget: a retry storm (every slot hanging, every
        # attempt timing out) must exhaust, not spin
        events = 0
        max_events = max(64, 16 * self.buffer_size
                         + 4 * self.concurrency * (self.max_retries + 1))
        while (len(merged) < self.buffer_size and st["in_flight"]
               and events < max_events):
            events += 1
            entry = heapq.heappop(st["in_flight"])
            key, _, cid, base_p, base_s, v0, t_disp, attempt, kind, t_fin = \
                entry
            if not np.isfinite(key):
                # hung dispatch with no watchdog armed: nothing in flight
                # can ever complete sooner — park it and return short
                # rather than advance the clock to infinity
                heapq.heappush(st["in_flight"], entry)
                break
            if kind:
                faulted[cid] = kind
            if key < t_fin:
                # watchdog fired before completion: abandon this attempt
                clock = max(clock, key)
                if attempt < self.max_retries:
                    retries[cid] = retries.get(cid, 0) + 1
                    self._dispatch(loop, r, cid, clock,
                                   attempt=attempt + 1)
                else:
                    dropped.append(cid)
                    self._refill(loop, r, clock)
                continue
            clock = max(clock, t_fin)
            if kind == "crash":
                # compute spent, update lost — free the slot and move on
                dropped.append(cid)
                self._refill(loop, r, clock)
                continue
            p_i, s_i, loss = loop.train_one_fn(cid, base_p, base_s, r)
            if kind in CORRUPT_KINDS:
                p_i = apply_fault_to_update(
                    kind, base_p, p_i,
                    amplify=loop.faults.amplify if loop.faults else 50.0)
                if kind in ("nan", "inf"):
                    loss = float("nan")
            stale = st["version"] - v0
            w = loop.client_weight(cid) * (1.0 + stale) ** -self.staleness_power
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                p_i, base_p)
            if loop.faults is not None and not all(
                    bool(np.isfinite(np.asarray(x)).all())
                    for x in jax.tree.leaves(delta)):
                # merge-time screen: never fold a non-finite delta into the
                # running model (only armed alongside the injector — a
                # clean run keeps the legacy merge arithmetic untouched)
                dropped.append(cid)
                losses[cid] = loss
                self._refill(loop, r, clock)
                continue
            merged.append((delta, s_i, w))
            completed.append(cid)
            losses[cid] = loss
            staleness[cid] = stale
            # backfill the freed slot immediately (at the completion time)
            self._refill(loop, r, clock)
        if merged:
            params, state = loop.get_model_fn()
            wsum = sum(w for _, _, w in merged)
            agg_delta = None
            agg_state = None
            for delta, s_i, w in merged:
                scaled = jax.tree.map(lambda d: (w / wsum) * d, delta)
                ssc = jax.tree.map(
                    lambda s: (w / wsum) * s.astype(jnp.float32), s_i)
                agg_delta = scaled if agg_delta is None else jax.tree.map(
                    jnp.add, agg_delta, scaled)
                agg_state = ssc if agg_state is None else jax.tree.map(
                    jnp.add, agg_state, ssc)
            new_p = jax.tree.map(
                lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
                params, agg_delta)
            new_s = jax.tree.map(lambda s, a: a.astype(s.dtype), state,
                                 agg_state)
            loop.set_model_fn(new_p, new_s)
            st["version"] += 1
        return RoundRecord(r, completed, losses, dropped=dropped,
                           t_start=t0, duration=clock - t0, t_end=clock,
                           policy=self.name, staleness=staleness,
                           faults=faulted, retries=retries)

    def _dispatch(self, loop: "FederatedLoop", r: int, cid: int, now: float,
                  *, attempt: int = 0, times: Optional[Dict] = None,
                  base=None):
        """Push one in-flight entry. Heap key = min(completion, watchdog
        deadline); a hang completes at +inf and only the watchdog (when
        armed) can reclaim the slot. Retries re-draw the fault gate on a
        per-attempt perturbed round index — a transient hang clears, a
        persistently faulty client exhausts ``max_retries``."""
        st = loop.async_state
        if times is None:
            times = loop.times([cid], r)
        if base is None:
            base = loop.snapshot_fn()
        kind = None
        if loop.faults is not None:
            kind = loop.faults.schedule(
                [cid], r if attempt == 0 else r + 7919 * attempt).get(cid)
        t_fin = np.inf if kind == "hang" else now + times[cid]
        key = t_fin
        if self.timeout_s is not None:
            key = min(t_fin, now + self.timeout_s
                      * self.retry_backoff ** attempt)
        st["seq"] += 1
        heapq.heappush(st["in_flight"],
                       (key, st["seq"], cid, base[0], base[1],
                        st["version"], now, attempt, kind, t_fin))

    def _refill(self, loop: "FederatedLoop", r: int, now: float):
        st = loop.async_state
        while len(st["in_flight"]) < self.concurrency:
            busy = {e[2] for e in st["in_flight"]}
            avail = [c for c in loop.available(r) if c not in busy]
            if not avail:
                return
            sel = [c for c in loop.select_fn(r, avail) if c not in busy]
            sel = sel[:self.concurrency - len(st["in_flight"])]
            if not sel:
                return
            times = loop.times(sel, r)
            base = loop.snapshot_fn()
            for cid in sel:
                self._dispatch(loop, r, cid, now, times=times, base=base)


_POLICIES = {"sync": SyncAggregation, "deadline": DeadlineAggregation,
             "async": AsyncBufferedAggregation,
             "async-buffered": AsyncBufferedAggregation}


def resolve_policy(policy) -> Any:
    """'sync' | 'deadline' | 'async' | policy instance -> policy instance."""
    if isinstance(policy, str):
        try:
            return _POLICIES[policy]()
        except KeyError:
            raise ValueError(f"unknown aggregation policy {policy!r}; "
                             f"choose from {sorted(set(_POLICIES))}")
    return policy


# ---------------------------------------------------------------------------
# The one loop
# ---------------------------------------------------------------------------


@dataclass
class FederatedLoop:
    """Selection -> local training -> aggregation -> observation per tick.

    Hook surface (all trainers are closures over their own model state):

      select_fn(round_idx, available_ids) -> cohort ids
      train_fn(cohort, round_idx, *, sequential=None) -> {cid: mean loss}
          runs the engine dispatch AND applies the aggregate to the
          trainer's model state; ``sequential`` forwards the deadline
          policy's straggler escape hatch. With a ``faults`` injector
          configured the hook is additionally called with
          ``faults={cid: kind}`` on rounds where corruption fired (the
          kwarg is omitted on clean rounds, so stub hooks keep working).
      on_round(RoundRecord) -> truthy to stop (pace freeze, budget, ...)

    Async hooks (only needed for ``AsyncBufferedAggregation``):

      snapshot_fn() -> (params, state) current model refs
      train_one_fn(cid, params, state, round_idx) -> (params_i, state_i, loss)
      get_model_fn() / set_model_fn(params, state)

    ``clients`` may be omitted (LM pod training drives the same loop with
    ``client_ids`` only). ``time_model=None`` builds the default
    ``|D_i|/c_i`` model from the fleet — identical to the seed servers'
    straggler arithmetic — or zero times with no fleet.

    ``mesh`` (``launch.mesh.make_client_mesh``) shards the time model's
    per-client columns along the cohort axis so the virtual-clock kernel
    runs over the same placement as the sharded round engine; ``None`` is
    the single-device default.

    A minimal loop — stub hooks, three clients, zero-cost time model —
    showing one tick per round and the policy-agnostic record it leaves:

    >>> loop = FederatedLoop(
    ...     select_fn=lambda r, avail: avail[:2],
    ...     train_fn=lambda cohort, r, sequential=None: {c: 0.5
    ...                                                  for c in cohort},
    ...     client_ids=[0, 1, 2])
    >>> recs = loop.run(2)
    >>> [(rec.round_idx, rec.selected) for rec in recs]
    [(0, [0, 1]), (1, [0, 1])]
    >>> loop.clock                    # no time model -> free rounds
    0.0
    """

    select_fn: Callable[[int, List[int]], List[int]] = None
    train_fn: Callable[..., Dict[int, float]] = None
    clients: Optional[Dict[int, Any]] = None
    client_ids: Optional[List[int]] = None
    aggregation: Union[str, Any] = "sync"
    time_model: Optional[FleetTimeModel] = None
    availability: Optional[AvailabilityTrace] = None
    faults: Optional[FaultInjector] = None
    mesh: Any = None
    on_round: Optional[Callable[[RoundRecord], Optional[bool]]] = None
    snapshot_fn: Optional[Callable] = None
    train_one_fn: Optional[Callable] = None
    get_model_fn: Optional[Callable] = None
    set_model_fn: Optional[Callable] = None
    clock: float = 0.0
    history: List[RoundRecord] = field(default_factory=list)
    async_state: Dict = field(default_factory=lambda: {
        "in_flight": [], "version": 0, "seq": 0})

    def __post_init__(self):
        self.aggregation = resolve_policy(self.aggregation)
        if self.client_ids is None:
            self.client_ids = (sorted(self.clients) if self.clients else [])
        if self.time_model is None and self.clients:
            self.time_model = FleetTimeModel.from_clients(self.clients)
        if self.mesh is not None and self.time_model is not None:
            self.time_model = self.time_model.shard(self.mesh)

    # ----- plumbing the policies call into -----

    def available(self, round_idx: int) -> List[int]:
        if self.availability is None:
            return list(self.client_ids)
        return self.availability.available(self.client_ids, round_idx)

    def dropouts(self, cohort: Sequence[int], round_idx: int) -> List[int]:
        if self.availability is None:
            return []
        return self.availability.dropouts(cohort, round_idx)

    def times(self, cohort: Sequence[int], round_idx: int) -> Dict[int, float]:
        if self.time_model is None:
            return {int(c): 0.0 for c in cohort}
        return self.time_model.cohort_times(cohort, round_idx)

    def client_weight(self, cid: int) -> float:
        if self.clients and cid in self.clients:
            return float(self.clients[cid].num_samples)
        return 1.0

    def fault_schedule(self, cohort: Sequence[int],
                       round_idx: int) -> Dict[int, str]:
        """{cid: kind} from the configured ``FaultInjector`` ({} without
        one). Order-independent, so policies may query any subset."""
        if self.faults is None:
            return {}
        return self.faults.schedule(cohort, round_idx)

    def run_train(self, cohort: Sequence[int], round_idx: int, *,
                  schedule: Optional[Dict[int, str]] = None,
                  **kw) -> Tuple[Dict[int, float], List[int]]:
        """Train ``cohort`` through ``train_fn`` with this round's fault
        schedule applied: crash/hang clients lose their update before it
        reaches the server (returned as the ``crashed`` list), corruption
        kinds are forwarded to the trainer hook via ``faults=...`` (only
        when non-empty, so legacy two-arg hooks keep working unfaulted).
        Returns ({cid: loss}, crashed)."""
        cohort = list(cohort)
        sched = self.fault_schedule(cohort, round_idx) \
            if schedule is None else schedule
        crashed = [c for c in cohort if sched.get(c) in ("crash", "hang")]
        live = [c for c in cohort if sched.get(c) not in ("crash", "hang")]
        if not live:
            return {}, crashed
        corrupt = {c: k for c, k in sched.items()
                   if k in CORRUPT_KINDS and c in set(live)}
        if corrupt:
            kw = dict(kw, faults=corrupt)
        return self.train_fn(live, round_idx, **kw), crashed

    # ----- driving -----

    def run(self, n_rounds: int, *, start_round: int = 0) -> List[RoundRecord]:
        """Run ``n_rounds`` ticks with global indices starting at
        ``start_round`` (global indices keep per-(client, round) batch plans
        and jitter draws stable across stages and resume)."""
        out: List[RoundRecord] = []
        for r in range(start_round, start_round + n_rounds):
            rec = self.aggregation.tick(self, r)
            self.clock = rec.t_end
            self.history.append(rec)
            out.append(rec)
            if self.on_round is not None and self.on_round(rec):
                break
        return out


# ---------------------------------------------------------------------------
# Checkpoint/resume helpers (arrays only — CheckpointManager-ready)
# ---------------------------------------------------------------------------


def pack_rng_state(rs: np.random.RandomState) -> Dict[str, np.ndarray]:
    """A numpy RandomState stream as checkpointable arrays."""
    name, keys, pos, has_gauss, cached = rs.get_state()
    assert name == "MT19937"
    return {"keys": np.asarray(keys, np.uint32),
            "pos": np.asarray([pos, has_gauss], np.int64),
            "gauss": np.asarray([cached], np.float64)}


def unpack_rng_state(tree: Dict[str, np.ndarray]) -> np.random.RandomState:
    rs = np.random.RandomState(0)
    pos, has_gauss = (int(x) for x in np.asarray(tree["pos"]))
    rs.set_state(("MT19937", np.asarray(tree["keys"], np.uint32), pos,
                  has_gauss, float(np.asarray(tree["gauss"])[0])))
    return rs


def selector_state_tree(selector) -> Dict[str, np.ndarray]:
    """Serialize a ``ParticipantSelector`` / ``VectorizedSelector``:
    fitted communities (ragged -> flat + offsets), the epsilon-greedy
    bandit's utility/recency tables, and the internal round counters that
    key the per-round ``mix_seed`` RNG streams."""
    from repro.checkpoint.ckpt import pack_ragged
    if hasattr(selector, "state_dict"):       # VectorizedSelector
        return selector.state_dict()
    t: Dict[str, np.ndarray] = {}
    comms = getattr(selector, "_communities", None)
    if comms:
        ragged = pack_ragged(comms)
        t["comm_flat"], t["comm_offsets"] = ragged["flat"], ragged["offsets"]
    bandit = getattr(selector, "_bandit", None)
    if bandit is not None:
        ids = sorted(bandit._util)
        t["bandit_ids"] = np.asarray(ids, np.int64)
        t["bandit_util"] = np.asarray([bandit._util[i] for i in ids],
                                      np.float64)
        t["bandit_seen"] = np.asarray(
            [bandit._last_seen.get(i, -1) for i in ids], np.int64)
        t["bandit_round"] = np.asarray([bandit._round], np.int64)
    if hasattr(selector, "_round"):           # VectorizedSelector
        t["round"] = np.asarray([selector._round], np.int64)
    return t


def load_selector_state(selector, tree: Dict[str, np.ndarray]) -> None:
    from repro.checkpoint.ckpt import unpack_ragged
    if hasattr(selector, "load_state_dict"):  # VectorizedSelector
        selector.load_state_dict(tree)
        return
    if "comm_flat" in tree:
        selector._communities = unpack_ragged(
            {"flat": tree["comm_flat"], "offsets": tree["comm_offsets"]})
    bandit = getattr(selector, "_bandit", None)
    if bandit is not None and "bandit_ids" in tree:
        ids = [int(i) for i in np.asarray(tree["bandit_ids"])]
        bandit._util = {i: float(u) for i, u in
                        zip(ids, np.asarray(tree["bandit_util"]))}
        bandit._last_seen = {i: int(s) for i, s in
                             zip(ids, np.asarray(tree["bandit_seen"]))
                             if int(s) >= 0}
        bandit._round = int(np.asarray(tree["bandit_round"])[0])
    if hasattr(selector, "_round") and "round" in tree:
        selector._round = int(np.asarray(tree["round"])[0])


def pack_float_map(d: Dict[int, float]) -> Dict[str, np.ndarray]:
    ids = sorted(d)
    return {"ids": np.asarray(ids, np.int64),
            "vals": np.asarray([d[i] for i in ids], np.float64)}


def unpack_float_map(tree: Dict[str, np.ndarray]) -> Dict[int, float]:
    return {int(i): float(v) for i, v in
            zip(np.asarray(tree["ids"]), np.asarray(tree["vals"]))}


def tree_like(template, restored):
    """Cast a restored (numpy) tree onto the dtypes/structure of a live
    template tree — the elastic-restore idiom shared by the servers."""
    return jax.tree.map(lambda a, b: jnp.asarray(b, a.dtype), template,
                        restored)
