"""Uplink update compression: top-k sparsification with error feedback.

Clients upload parameter *deltas*; top-k keeps the k largest-magnitude
entries per tensor and accumulates the residual locally (error feedback), so
compression error is corrected over rounds instead of lost. Used on the
federated uplink (client -> server) and available for the pod-level
cross-silo aggregation.

Two implementations share one selection rule:

  * the host numpy path (``topk_compress``/``topk_decompress``/
    ``ErrorFeedback``) — the small-N reference, and the wire format for a
    real deployment;
  * the in-graph path (``ingraph_topk``/``ingraph_sparse_aggregate``) —
    ``lax.top_k`` + scatter ops meant to run INSIDE the fused round dispatch
    (fl/engine.py), so compressed rounds never round-trip through host numpy.

Selection rule (both paths): take the k largest |values|, breaking magnitude
ties toward the LOWER flat index (``lax.top_k``'s documented behavior,
mirrored on host by a stable argsort), then transmit entries in ascending
index order. This makes compressed payloads byte-reproducible across
platforms — ``np.argpartition``, used previously, returns a
platform-dependent subset AND order under ties.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def topk_keep(n: int, ratio: float) -> int:
    """Entries kept per leaf — shared by the host and in-graph paths."""
    return max(1, int(n * ratio))


def deterministic_topk_indices(flat: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k largest |values|, ties to the lower index, returned
    ascending. Host mirror of the in-graph ``lax.top_k`` selection."""
    order = np.argsort(-np.abs(flat), kind="stable")[:k]
    return np.sort(order)


def topk_compress(delta, ratio: float) -> Dict:
    """Keep the top `ratio` fraction of entries per leaf. Returns a sparse
    representation {path: (indices, values, shape)} with indices ascending
    (deterministic payload — see module docstring)."""
    out = {}
    for i, leaf in enumerate(jax.tree.leaves(delta)):
        flat = np.asarray(leaf, np.float32).ravel()
        idx = deterministic_topk_indices(flat, topk_keep(len(flat), ratio))
        out[i] = (idx.astype(np.int32), flat[idx], leaf.shape)
    return out


def topk_decompress(sparse: Dict, treedef_like) -> object:
    leaves = []
    for i, leaf in enumerate(jax.tree.leaves(treedef_like)):
        idx, vals, shape = sparse[i]
        flat = np.zeros(int(np.prod(shape)), np.float32)
        flat[idx] = vals
        leaves.append(jnp.asarray(flat.reshape(shape), leaf.dtype))
    return jax.tree.unflatten(jax.tree.structure(treedef_like), leaves)


def compressed_bytes(sparse: Dict) -> int:
    return sum(idx.nbytes + vals.nbytes for idx, vals, _ in sparse.values())


# ---------------------------------------------------------------------------
# In-graph primitives (consumed by fl/engine.py inside the fused dispatch)
# ---------------------------------------------------------------------------


def ingraph_topk(flat: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k |values| of a flat vector, in-graph. ``lax.top_k`` breaks ties
    toward the lower index (same rule as ``deterministic_topk_indices``);
    the selected indices are re-sorted ascending so the on-wire order
    matches the host path bit-for-bit. Returns (indices i32 [k], values [k])."""
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = jnp.sort(idx).astype(jnp.int32)
    return idx, jnp.take(flat, idx)


def ingraph_sparse_aggregate(idx: jnp.ndarray, vals: jnp.ndarray,
                             weights: jnp.ndarray, length: int,
                             use_pallas: bool = False) -> jnp.ndarray:
    """Server-side Eq. 1 aggregation over K clients' sparse uplinks, as one
    scatter-add (segment-sum over the flat parameter index): dense [length]
    result without ever densifying per-client payloads on host.

    idx/vals: [K, k] per-client sparse entries; weights: [K] normalized.
    ``use_pallas`` routes through the single-launch cohort fold in
    kernels/sparse_agg.py (same semantics, incl. duplicate-index
    accumulation; the XLA scatter stays the default and the bit-compat
    reference)."""
    if use_pallas:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.sparse_cohort_add(idx, vals, weights, length)
    contrib = (weights[:, None] * vals).reshape(-1)
    return jnp.zeros(length, jnp.float32).at[idx.reshape(-1)].add(contrib)


def ingraph_compress_leaf(flat_start: jnp.ndarray, flat_end: jnp.ndarray,
                          residual: jnp.ndarray, weights: jnp.ndarray,
                          ratio: float, use_pallas: bool = False):
    """One leaf of the fused compressed round: per-client delta + error
    feedback -> ``lax.top_k`` sparsify -> scatter-add aggregation.

    flat_start: [L] round-start params (f32); flat_end: [K, L] per-client
    trained params (f32); residual: [K, L] carried error-feedback state;
    weights: [K] normalized Eq. 1 weights. Returns (aggregated [L] f32,
    new residual [K, L], idx [K, k], vals [K, k]). ``use_pallas`` selects
    the Pallas cohort fold for the aggregation scatter only — selection and
    error feedback are identical on both paths, so residual state never
    diverges between them.
    """
    L = flat_start.shape[0]
    k = topk_keep(L, ratio)
    delta = flat_end - flat_start[None, :] + residual
    idx, vals = jax.vmap(lambda d: ingraph_topk(d, k))(delta)
    sent = jax.vmap(
        lambda i, v: jnp.zeros(L, jnp.float32).at[i].set(v))(idx, vals)
    new_residual = delta - sent
    agg = flat_start + ingraph_sparse_aggregate(idx, vals, weights, L,
                                                use_pallas=use_pallas)
    return agg, new_residual, idx, vals


@dataclass
class ErrorFeedback:
    """Per-client residual accumulator for biased compressors (host path)."""

    ratio: float = 0.01
    _residual: Optional[object] = None

    def compress(self, delta) -> Tuple[Dict, object]:
        if self._residual is not None:
            delta = jax.tree.map(lambda d, r: d + r, delta, self._residual)
        sparse = topk_compress(delta, self.ratio)
        decompressed = topk_decompress(sparse, delta)
        self._residual = jax.tree.map(lambda d, q: d - q.astype(jnp.float32),
                                      jax.tree.map(lambda x: x.astype(jnp.float32), delta),
                                      decompressed)
        return sparse, decompressed
