"""Uplink update compression: top-k sparsification with error feedback.

Clients upload parameter *deltas*; top-k keeps the k largest-magnitude
entries per tensor and accumulates the residual locally (error feedback), so
compression error is corrected over rounds instead of lost. Used on the
federated uplink (client -> server) and available for the pod-level
cross-silo aggregation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def topk_compress(delta, ratio: float) -> Dict:
    """Keep the top `ratio` fraction of entries per leaf. Returns a sparse
    representation {path: (indices, values, shape)}."""
    out = {}
    for i, leaf in enumerate(jax.tree.leaves(delta)):
        flat = np.asarray(leaf, np.float32).ravel()
        k = max(1, int(len(flat) * ratio))
        idx = np.argpartition(np.abs(flat), -k)[-k:]
        out[i] = (idx.astype(np.int32), flat[idx], leaf.shape)
    return out


def topk_decompress(sparse: Dict, treedef_like) -> object:
    leaves = []
    for i, leaf in enumerate(jax.tree.leaves(treedef_like)):
        idx, vals, shape = sparse[i]
        flat = np.zeros(int(np.prod(shape)), np.float32)
        flat[idx] = vals
        leaves.append(jnp.asarray(flat.reshape(shape), leaf.dtype))
    return jax.tree.unflatten(jax.tree.structure(treedef_like), leaves)


def compressed_bytes(sparse: Dict) -> int:
    return sum(idx.nbytes + vals.nbytes for idx, vals, _ in sparse.values())


@dataclass
class ErrorFeedback:
    """Per-client residual accumulator for biased compressors."""

    ratio: float = 0.01
    _residual: Optional[object] = None

    def compress(self, delta) -> Tuple[Dict, object]:
        if self._residual is not None:
            delta = jax.tree.map(lambda d, r: d + r, delta, self._residual)
        sparse = topk_compress(delta, self.ratio)
        decompressed = topk_decompress(sparse, delta)
        self._residual = jax.tree.map(lambda d, q: d - q.astype(jnp.float32),
                                      jax.tree.map(lambda x: x.astype(jnp.float32), delta),
                                      decompressed)
        return sparse, decompressed
