"""FL servers: SmartFreeze orchestration + vanilla FedAvg (CNN testbed).

SmartFreezeServer runs the full paper pipeline end to end:
  (1) init: split model into T stages, collect local monitors' reports
      (memory, capability, one-shot output-layer gradients, local loss);
  (2) RL-CD communities from the Eq. 8 similarity matrix;
  (3) per stage: participant selection (Eq. 11-14) -> rounds of local
      training -> Eq. 1 aggregation -> pace controller observes the block
      perturbation and freezes the stage when converged;
  (4) model growth until the full model is trained.

Round execution is delegated to ``fl/engine.py`` (one fused
vmap-over-clients dispatch per round plus the frozen-prefix feature cache),
and round *orchestration* to ``fl/sim.py``: both servers are hook bundles
over the virtual-time ``FederatedLoop``, so they run under any aggregation
policy — ``sync`` (Eq. 7 barrier), ``deadline`` (partial aggregation over
clients finishing before T_dl; the legacy ``deadline_factor`` knob maps
onto it), or ``async``/FedBuff (staleness-weighted buffered updates) — with
per-round virtual-clock accounting, availability/dropout traces, and
heterogeneous link rates via ``FleetTimeModel``.

Full-experiment checkpoint/resume rides on ``CheckpointManager``
(``run(..., ckpt_manager=..., ckpt_every=..., resume=True)``): the pace
controller window, selector/bandit streams, error-feedback residual pools,
``_last_loss`` table and the virtual clock all serialize, so a restored
SmartFreeze run continues bit-identically — including across stage-freeze
boundaries.

``selector`` accepts either the list-based ``ParticipantSelector`` or the
population-scale ``core.selector.vectorized.VectorizedSelector`` — both
implement ``fit_communities`` + ``select`` with the same contract.
"""
from __future__ import annotations

import dataclasses
import logging
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import freezing_cnn as fz
from repro.core.memory_model import (CACHE_TIER_DTYPES, CACHE_TIERS,
                                     cache_tier_ladder,
                                     cnn_feature_cache_bytes,
                                     cnn_stage_memory_bytes)
from repro.core.time_model import cnn_cached_compute_scale
from repro.core.pace import PaceController
from repro.core.selector import ParticipantSelector
from repro.core.selector.selection import InfeasibleStageError
from repro.core.selector.similarity import similarity_matrix
from repro.fl.client import SimClient
from repro.fl.engine import AGGREGATORS, RoundEngine, weighted_avg
from repro.fl.faults import FaultInjector
from repro.fl.sim import (AvailabilityTrace, DeadlineAggregation,
                          FederatedLoop, FleetTimeModel, SyncAggregation,
                          load_selector_state, pack_float_map,
                          pack_rng_state, resolve_policy, selector_state_tree,
                          tree_like, unpack_float_map, unpack_rng_state)
from repro.models.cnn import CNN
from repro.optim import Optimizer, sgd

__all__ = ["SmartFreezeServer", "FedAvgServer", "RoundResult",
           "cnn_stage_memory_bytes", "cnn_feature_cache_bytes",
           "weighted_avg"]


@dataclass
class RoundResult:
    round_idx: int
    stage: int
    loss: float
    test_acc: Optional[float] = None
    selected: List[int] = field(default_factory=list)
    perturbation: Optional[float] = None
    frozen: bool = False
    uplink_bytes: Optional[int] = None   # cohort uplink payload this round
    duration: Optional[float] = None     # virtual seconds this round took
    virtual_time: Optional[float] = None  # virtual clock at round end
    dropped: List[int] = field(default_factory=list)  # deadline/dropout
    cache_bytes: Optional[int] = None    # resident feature cache (stored dtype)
    screened: List[int] = field(default_factory=list)  # updates screened out
    rolled_back: bool = False            # this round triggered a freeze rollback


_log = logging.getLogger(__name__)


def _mean_loss(losses: Dict[int, float],
               prev: Optional[float] = None) -> float:
    """Mean of the FINITE per-client losses this round. A starved round —
    empty cohort, or every reported loss non-finite (all clients
    crashed/faulted) — returns ``prev`` when available so the history (and
    anything smoothing over it) never ingests a NaN, and logs the
    starvation explicitly instead of letting it travel silently."""
    vals = [v for v in losses.values() if np.isfinite(v)]
    if vals:
        return float(np.mean(vals))
    _log.warning("starved round: %d client losses reported, none finite; "
                 "carrying previous loss %s", len(losses), prev)
    return float(prev) if prev is not None else float("nan")


class SmartFreezeServer:
    def __init__(self, model: CNN, clients: List[SimClient], *,
                 optimizer_fn: Callable[[], Optimizer] = lambda: sgd(0.05),
                 clients_per_round: int = 10, local_epochs: int = 1,
                 batch_size: int = 32, rounds_per_stage: int = 60,
                 pace_kwargs: Optional[dict] = None,
                 op_kind: str = "conv", selector: Optional[ParticipantSelector] = None,
                 deadline_factor: float = 0.0, seed: int = 0,
                 fused: bool = True, cache_features: bool = True,
                 cache_tiers: Union[str, tuple, list] = ("f32",),
                 compute_dtype: Optional[str] = None,
                 cache_time_scale: bool = False,
                 compress_ratio: Optional[float] = None,
                 aggregation: Union[str, object, None] = None,
                 time_model: Optional[FleetTimeModel] = None,
                 availability: Optional[AvailabilityTrace] = None,
                 mesh=None, screen_updates: bool = False,
                 aggregator: str = "mean",
                 faults: Optional[FaultInjector] = None,
                 freeze_rollback: bool = False,
                 rollback_guard: float = 0.5, rollback_window: int = 8,
                 rollback_patience: int = 2, max_rollbacks: int = 1,
                 use_pallas: bool = False):
        self.model = model
        self.clients = {c.client_id: c for c in clients}
        self.optimizer_fn = optimizer_fn
        self.k = clients_per_round
        self.local_epochs = local_epochs
        self.batch_size = batch_size
        self.rounds_per_stage = rounds_per_stage
        self.pace_kwargs = pace_kwargs or {}
        self.op_kind = op_kind
        self.selector = selector or ParticipantSelector(seed=seed)
        self.deadline_factor = deadline_factor  # >0: drop stragglers past deadline
        self.seed = seed
        self.fused = fused
        self.cache_features = cache_features
        # admission ladder, most exact first. "all" = f32 -> fp16 -> int8;
        # the ("f32",) default keeps pre-tier runs bit-identical.
        self.cache_tiers = (CACHE_TIERS if cache_tiers == "all"
                            else tuple(cache_tiers))
        unknown = [t for t in self.cache_tiers if t not in CACHE_TIERS]
        if unknown:
            raise ValueError(f"unknown cache tiers {unknown}; "
                             f"choose from {CACHE_TIERS}")
        self.compute_dtype = compute_dtype
        self.cache_time_scale = cache_time_scale
        self.compress_ratio = compress_ratio
        self.aggregation = aggregation
        self.time_model = time_model
        self.availability = availability
        # client-axis mesh (launch.mesh.make_client_mesh): shard_map the
        # fused round + the fleet time kernel over the cohort axis; None is
        # the bit-identical single-device path. Selection stays host-side,
        # so sharded and single-device runs pick identical cohorts.
        self.mesh = mesh
        # ISSUE 7 defenses: in-graph update screening / robust aggregation
        # (threaded into every stage engine), deterministic fault injection
        # (handed to the FederatedLoop), and post-freeze divergence rollback
        if aggregator not in AGGREGATORS:
            raise ValueError(f"unknown aggregator {aggregator!r}; "
                             f"choose from {AGGREGATORS}")
        self.screen_updates = screen_updates
        self.aggregator = aggregator
        self.faults = faults
        self.freeze_rollback = freeze_rollback
        self.rollback_guard = rollback_guard
        self.rollback_window = rollback_window
        self.rollback_patience = rollback_patience
        self.max_rollbacks = max_rollbacks
        # Pallas hot-path kernels (kernels/): compressed-uplink cohort fold
        # + in-register int8 dequant GEMM for quant-aware cached losses.
        # Default False = the exact XLA graphs (bit-compat escape hatch).
        self.use_pallas = use_pallas
        self.rollbacks = 0                   # freeze rollbacks taken so far
        self.history: List[RoundResult] = []
        self.cache_tier_plan: Dict[int, Optional[str]] = {}  # current stage
        self._last_loss: Dict[int, float] = {}
        self.image_size = int(next(iter(self.clients.values())).data["x"].shape[1])

    def _policy(self):
        if self.aggregation is not None:
            return resolve_policy(self.aggregation)
        if self.deadline_factor > 0:
            return DeadlineAggregation(factor=self.deadline_factor)
        return SyncAggregation()

    # ----- bootstrap: similarity from output-layer gradients (Eq. 8) -----

    def bootstrap_similarity(self, params, state) -> np.ndarray:
        grads = {}
        for cid, c in self.clients.items():
            x = jnp.asarray(c.data["x"][:64])
            y = jnp.asarray(c.data["y"][:64])

            def head_loss(fc):
                logits, _ = self.model.apply({**params, "fc": fc}, state, x,
                                             train=False)
                lf = logits.astype(jnp.float32)
                logz = jax.scipy.special.logsumexp(lf, axis=-1)
                gold = jnp.take_along_axis(lf, y[:, None], axis=-1)[:, 0]
                return jnp.mean(logz - gold)

            g = jax.grad(head_loss)(params["fc"])
            grads[cid] = np.concatenate([np.asarray(l, np.float32).ravel()
                                         for l in jax.tree.leaves(g)])
        return similarity_matrix(grads)

    # ----- per-stage engine construction -----

    def _stage_engine(self, stage: int, frozen, bn_state) -> RoundEngine:
        model = self.model
        cached_loss = feature_fn = None
        if stage > 0:
            cached_loss = fz.cnn_cached_stage_loss_fn(model, stage,
                                                      op_kind=self.op_kind)
            feature_fn = (lambda x, _fr=frozen, _st=bn_state:
                          fz.cnn_prefix_features(model, _fr, _st, x, stage))
        return RoundEngine(
            loss_fn=fz.cnn_stage_loss_fn(model, stage, op_kind=self.op_kind),
            optimizer=self.optimizer_fn(), frozen=frozen,
            cached_loss_fn=cached_loss, feature_fn=feature_fn,
            batch_size=self.batch_size, local_epochs=self.local_epochs,
            clip_norm=10.0, fused=self.fused,
            compress_ratio=self.compress_ratio,
            compute_dtype=self.compute_dtype, mesh=self.mesh,
            screen=self.screen_updates, aggregator=self.aggregator,
            use_pallas=self.use_pallas)

    def _cache_plan(self, stage: int) -> Dict[int, Optional[str]]:
        """Memory-model admission ladder (Eq. 12 per tier): walk
        ``cache_tiers`` most-exact-first and grant each client the first
        tier whose stage requirement PLUS its shard's prefix activations at
        that tier's storage dtype fits; ``None`` declines the cache (full
        prefix recompute). With the default f32-only ladder this reduces to
        the original boolean gate."""
        if not self.cache_features or stage == 0:
            return {}
        plan = {}
        for cid, c in self.clients.items():
            plan[cid] = cache_tier_ladder(
                c.memory_bytes,
                lambda t, _n=c.num_samples: cnn_stage_memory_bytes(
                    self.model, stage, self.batch_size, self.image_size,
                    cache_samples=_n, cache_dtype=CACHE_TIER_DTYPES[t]),
                tiers=self.cache_tiers)
        return plan

    # ----- main loop (one FederatedLoop per stage) -----

    def run(self, params, state, *, eval_fn: Optional[Callable] = None,
            eval_every: int = 10, total_rounds: Optional[int] = None,
            schedule: Optional[List[int]] = None,
            ckpt_manager=None, ckpt_every: int = 0,
            resume: bool = False) -> Dict:
        """schedule: optional fixed rounds-per-stage (pace-controller ablation).

        ``ckpt_manager``/``ckpt_every``: checkpoint the full experiment state
        every N completed rounds (and at stage freezes); ``resume=True``
        restores the latest committed checkpoint and continues the loss /
        perturbation / selection series bit-identically."""
        model = self.model
        n_stages = len(model.cfg.stage_sizes)
        budget = total_rounds or self.rounds_per_stage * n_stages
        policy = self._policy()
        clock = 0.0
        round_idx = 0
        start_stage = 0
        restored = None
        if resume and ckpt_manager is not None:
            try:
                restored = ckpt_manager.restore()
            except FileNotFoundError:
                restored = None
        if restored is None:
            sim = self.bootstrap_similarity(params, state)
            self.selector.fit_communities(sim)
        else:
            tree, meta = restored["tree"], restored["metadata"]
            load_selector_state(self.selector, tree["selector"])
            self._last_loss = unpack_float_map(tree["last_loss"])
            params = tree_like(params, tree["params"])
            state = tree_like(state, tree["state"])
            clock = float(meta["clock"])
            round_idx = int(meta["round_idx"]) + 1
            start_stage = int(meta["stage"])

        # rollback bookkeeping (ISSUE 7): armed right after a pace freeze
        # with a pre-freeze snapshot + loss reference; the next stage's
        # rounds are watched for a regression past the guard band. The
        # armed state is not serialized — a resumed run re-arms at its next
        # freeze (documented; rollback is a safety net, not part of the
        # bit-identical trajectory contract).
        rb_armed: Optional[Dict] = None
        recent_losses: List[float] = []
        stage = start_stage
        while stage < n_stages:
            mid = restored["metadata"] if (restored is not None
                                           and stage == start_stage) else None
            if schedule is not None:
                plan_rounds = schedule[stage]
            elif mid is not None:
                plan_rounds = int(mid["plan_rounds"])
            else:
                # pace-adaptive budget: early freezes hand their unused rounds
                # to later stages (reserve >=1 round per remaining stage)
                remaining_stages = n_stages - stage - 1
                plan_rounds = max(budget - round_idx - remaining_stages, 1)
            pace = PaceController(**self.pace_kwargs)
            frozen, active = fz.init_cnn_stage_active(
                model, params, stage, jax.random.PRNGKey(self.seed + stage),
                op_kind=self.op_kind)
            r_in_stage = 0
            if mid is not None:
                active = tree_like(active, restored["tree"]["active"])
                pace.load_state_dict(restored["tree"]["pace"])
                r_in_stage = int(mid["r_in_stage"]) + 1
            engine = self._stage_engine(stage, frozen, state)
            if mid is not None and "ef" in restored["tree"]:
                engine.load_ef_state(restored["tree"]["ef"])
            if mid is not None and "cache" in restored["tree"]:
                # resume consumes the EXACT cached bytes (tier assignments +
                # int8 quant scales) the crashed run trained on
                engine.load_cache_state(restored["tree"]["cache"])
            cache_ok = self._cache_plan(stage)
            self.cache_tier_plan = cache_ok
            mem_req = cnn_stage_memory_bytes(model, stage, self.batch_size,
                                             self.image_size)
            stage_done = mid is not None and (
                bool(mid.get("frozen")) or r_in_stage >= plan_rounds)
            flags = {"freeze": False, "rollback": False}

            if not stage_done:
                stage_base = params
                box = {"active": active, "state": state}
                time_fn = lambda ci: ci.num_samples / ci.capability

                def select_fn(r, avail):
                    infos = {cid: dataclasses.replace(
                        self.clients[cid].info(),
                        loss_sum=(self._last_loss.get(cid, 1e3)
                                  * self.clients[cid].num_samples))
                        for cid in avail}
                    try:
                        # Eq. 11-14: I_{t,i} = |D_i| * latest local loss
                        return self.selector.select(infos, self.k,
                                                    mem_required=mem_req,
                                                    stage_time_fn=time_fn)
                    except InfeasibleStageError:
                        if len(avail) < len(self.clients):
                            # a transient availability dip, not the stage
                            # being memory-infeasible: skip the round (it
                            # costs 0.0 virtual seconds) instead of
                            # aborting the run
                            return []
                        raise

                def train_fn(cohort, r, sequential=None, faults=None):
                    box["active"], box["state"], losses = engine.run_round(
                        self.clients, cohort, box["active"], box["state"], r,
                        use_cache=cache_ok, sequential=sequential,
                        faults=faults)
                    self._last_loss.update(
                        {c: v for c, v in losses.items() if np.isfinite(v)})
                    return losses

                def train_one_fn(cid, p, s, r):
                    p_i, s_i, losses = engine.run_round(
                        self.clients, [cid], p, s, r, use_cache=cache_ok,
                        sequential=True)
                    self._last_loss.update(losses)
                    return p_i, s_i, losses[cid]

                def set_model_fn(p, s):
                    box["active"], box["state"] = p, s

                def on_round(rec):
                    p = pace.observe(box["active"].get("stages", box["active"]))
                    prev = self.history[-1].loss if self.history else None
                    loss = _mean_loss(rec.losses, prev=prev)
                    do_freeze = pace.should_freeze() and schedule is None
                    # post-freeze divergence watch (armed by the previous
                    # stage's pace freeze): a sustained loss regression
                    # past the guard band rolls that freeze back
                    rolled = False
                    if rb_armed is not None and np.isfinite(loss):
                        if loss > rb_armed["ref"] + self.rollback_guard:
                            rb_armed["bad"] += 1
                        else:
                            rb_armed["bad"] = 0
                        if rb_armed["bad"] >= self.rollback_patience:
                            rolled = flags["rollback"] = True
                            do_freeze = False
                    flags["freeze"] = do_freeze
                    rr = RoundResult(rec.round_idx, stage, loss,
                                     selected=rec.selected, perturbation=p,
                                     frozen=do_freeze,
                                     uplink_bytes=engine.last_uplink_bytes,
                                     duration=rec.duration,
                                     virtual_time=rec.t_end,
                                     dropped=rec.dropped,
                                     cache_bytes=engine.cache_nbytes(),
                                     screened=sorted(
                                         c for c, s in
                                         engine.last_screened.items() if s),
                                     rolled_back=rolled)
                    if np.isfinite(loss):
                        recent_losses.append(loss)
                        del recent_losses[:-self.rollback_window]
                    if eval_fn is not None and (rec.round_idx % eval_every == 0
                                                or do_freeze):
                        merged = fz.merge_cnn_params(model, stage_base, stage,
                                                     box["active"])
                        rr.test_acc = eval_fn(merged, box["state"], stage)
                    self.history.append(rr)
                    if ckpt_manager is not None and ckpt_every and (
                            (rec.round_idx + 1) % ckpt_every == 0 or do_freeze):
                        self._save_ckpt(ckpt_manager, rec, stage, stage_base,
                                        box, pace, engine, plan_rounds,
                                        rec.round_idx - round_idx + r_in_stage,
                                        do_freeze)
                    return do_freeze or rolled

                # copy before stamping the stage payload: a caller-supplied
                # time model may be shared across runs/trainers
                tm = (dataclasses.replace(self.time_model)
                      if self.time_model is not None
                      else FleetTimeModel.from_clients(self.clients))
                tm.payload_bytes = engine.per_client_uplink_bytes(active)
                if self.cache_time_scale:
                    # cached-mode clients skip the frozen-prefix forward
                    # every minibatch — their local step shrinks, which
                    # shifts round durations AND (under the deadline
                    # policy) who makes the cut, i.e. cohort composition
                    scale_of = {cid: cnn_cached_compute_scale(stage)
                                for cid, t in cache_ok.items() if t}
                    if scale_of:
                        tm = tm.with_compute_scale(scale_of)
                loop = FederatedLoop(
                    select_fn=select_fn, train_fn=train_fn,
                    clients=self.clients,
                    client_ids=list(self.clients),
                    aggregation=policy, time_model=tm, mesh=self.mesh,
                    availability=self.availability, faults=self.faults,
                    on_round=on_round,
                    snapshot_fn=lambda: (box["active"], box["state"]),
                    train_one_fn=train_one_fn,
                    get_model_fn=lambda: (box["active"], box["state"]),
                    set_model_fn=set_model_fn, clock=clock)
                n_run = max(min(plan_rounds - r_in_stage,
                                budget - round_idx), 0)
                done = loop.run(n_run, start_round=round_idx)
                round_idx += len(done)
                clock = loop.clock
                active, state = box["active"], box["state"]
                if flags["rollback"]:
                    # divergence past the guard band: unfreeze the rolled
                    # stage and restore its freeze-time snapshot, discarding
                    # every post-freeze round trained on the poisoned model
                    self.rollbacks += 1
                    _log.warning(
                        "freeze rollback: stage %d diverged post-freeze "
                        "(ref %.4f, guard %.2f) — unfreezing stage %d and "
                        "restoring its snapshot", stage, rb_armed["ref"],
                        self.rollback_guard, rb_armed["stage"])
                    params = rb_armed["params"]
                    state = rb_armed["state"]
                    stage = rb_armed["stage"]
                    rb_armed = None
                    recent_losses.clear()
                    if mid is not None:
                        restored = None
                    continue
            # --- model growth ---
            params = fz.merge_cnn_params(model, params, stage, active)
            rb_armed = None  # the watched stage survived its probation
            if (self.freeze_rollback and flags["freeze"]
                    and self.rollbacks < self.max_rollbacks
                    and stage + 1 < n_stages):
                # snapshot the just-frozen model + the pre-freeze loss
                # reference; the next stage's rounds run under watch
                ref = (float(np.mean(recent_losses)) if recent_losses
                       else float("inf"))
                rb_armed = {"stage": stage, "params": params, "state": state,
                            "ref": ref, "bad": 0}
            if mid is not None:
                restored = None  # consumed; later stages start fresh
            stage += 1
        return {"params": params, "state": state, "history": self.history,
                "rounds": round_idx, "virtual_time": clock}

    def _save_ckpt(self, mgr, rec, stage, stage_base, box, pace, engine,
                   plan_rounds, r_in_stage, frozen_flag):
        tree = {"params": stage_base, "active": box["active"],
                "state": box["state"], "pace": pace.state_dict(),
                "selector": selector_state_tree(self.selector),
                "last_loss": pack_float_map(self._last_loss)}
        ef = engine.ef_state()
        if ef is not None:
            tree["ef"] = ef
        # only when the cache grew/re-tiered since the last save — identical
        # feature bytes are not re-written every round (resume recomputes
        # deterministically when the restored checkpoint has no cache)
        cache = engine.cache_state_if_changed()
        if cache is not None:
            tree["cache"] = cache
        mgr.save(rec.round_idx, tree, metadata={
            "stage": stage, "round_idx": rec.round_idx,
            "r_in_stage": int(r_in_stage), "plan_rounds": int(plan_rounds),
            "clock": float(rec.t_end), "frozen": bool(frozen_flag)})


class FedAvgServer:
    """Vanilla FL baseline: full model every round, random selection.

    Runs on the same virtual-time ``FederatedLoop`` as SmartFreeze, so it
    takes the same ``aggregation`` / ``time_model`` / ``availability``
    knobs (sync / deadline / async-buffered) and reports per-round virtual
    durations in its history."""

    def __init__(self, model: CNN, clients: List[SimClient], *,
                 optimizer_fn=lambda: sgd(0.05), clients_per_round: int = 10,
                 local_epochs: int = 1, batch_size: int = 32,
                 mem_required: float = 0.0, seed: int = 0, fused: bool = True,
                 compress_ratio: Optional[float] = None,
                 compute_dtype: Optional[str] = None,
                 aggregation: Union[str, object, None] = None,
                 time_model: Optional[FleetTimeModel] = None,
                 availability: Optional[AvailabilityTrace] = None,
                 mesh=None, screen_updates: bool = False,
                 aggregator: str = "mean",
                 faults: Optional[FaultInjector] = None,
                 use_pallas: bool = False):
        self.model = model
        self.clients = {c.client_id: c for c in clients}
        self.optimizer_fn = optimizer_fn
        self.k = clients_per_round
        self.local_epochs = local_epochs
        self.batch_size = batch_size
        self.mem_required = mem_required
        self.seed = seed
        self.fused = fused
        self.compress_ratio = compress_ratio
        self.compute_dtype = compute_dtype
        self.aggregation = aggregation
        self.time_model = time_model
        self.availability = availability
        self.mesh = mesh
        if aggregator not in AGGREGATORS:
            raise ValueError(f"unknown aggregator {aggregator!r}; "
                             f"choose from {AGGREGATORS}")
        self.screen_updates = screen_updates
        self.aggregator = aggregator
        self.faults = faults
        self.use_pallas = use_pallas
        self.history: List[RoundResult] = []

    def run(self, params, state, *, rounds: int, eval_fn=None, eval_every=10,
            ckpt_manager=None, ckpt_every: int = 0, resume: bool = False):
        model = self.model
        n_stages = len(model.cfg.stage_sizes)

        def full_loss(p, frozen_unused, st, batch):
            return model.loss(p, st, batch, train=True)

        engine = RoundEngine(loss_fn=full_loss, optimizer=self.optimizer_fn(),
                             batch_size=self.batch_size,
                             local_epochs=self.local_epochs,
                             clip_norm=10.0, fused=self.fused,
                             compress_ratio=self.compress_ratio,
                             compute_dtype=self.compute_dtype,
                             mesh=self.mesh, screen=self.screen_updates,
                             aggregator=self.aggregator,
                             use_pallas=self.use_pallas)
        rng = np.random.RandomState(self.seed)
        eligible = [cid for cid, c in self.clients.items()
                    if c.memory_bytes >= self.mem_required]
        participation = len(eligible) / len(self.clients)
        clock = 0.0
        start_round = 0
        if resume and ckpt_manager is not None:
            try:
                ck = ckpt_manager.restore()
            except FileNotFoundError:
                ck = None
            if ck is not None:
                params = tree_like(params, ck["tree"]["params"])
                state = tree_like(state, ck["tree"]["state"])
                rng = unpack_rng_state(ck["tree"]["rng"])
                if "ef" in ck["tree"]:
                    engine.load_ef_state(ck["tree"]["ef"])
                clock = float(ck["metadata"]["clock"])
                start_round = int(ck["metadata"]["round_idx"]) + 1
        if not eligible or start_round >= rounds:
            return {"params": params, "state": state, "history": self.history,
                    "participation": participation, "virtual_time": clock}

        box = {"params": params, "state": state}
        elig_set = set(eligible)

        def select_fn(r, avail):
            cands = [c for c in avail if c in elig_set]
            if not cands:
                return []
            return list(rng.choice(cands, size=min(self.k, len(cands)),
                                   replace=False))

        def train_fn(cohort, r, sequential=None, faults=None):
            box["params"], box["state"], losses = engine.run_round(
                self.clients, cohort, box["params"], box["state"], r,
                sequential=sequential, faults=faults)
            return losses

        def train_one_fn(cid, p, s, r):
            p_i, s_i, losses = engine.run_round(self.clients, [cid], p, s, r,
                                                sequential=True)
            return p_i, s_i, losses[cid]

        def on_round(rec):
            prev = self.history[-1].loss if self.history else None
            rr = RoundResult(rec.round_idx, n_stages - 1,
                             _mean_loss(rec.losses, prev=prev),
                             selected=rec.selected,
                             uplink_bytes=engine.last_uplink_bytes,
                             duration=rec.duration, virtual_time=rec.t_end,
                             dropped=rec.dropped,
                             screened=sorted(
                                 c for c, s in engine.last_screened.items()
                                 if s))
            if eval_fn is not None and rec.round_idx % eval_every == 0:
                rr.test_acc = eval_fn(box["params"], box["state"], n_stages - 1)
            self.history.append(rr)
            if ckpt_manager is not None and ckpt_every and (
                    (rec.round_idx + 1) % ckpt_every == 0):
                tree = {"params": box["params"], "state": box["state"],
                        "rng": pack_rng_state(rng)}
                ef = engine.ef_state()
                if ef is not None:
                    tree["ef"] = ef
                ckpt_manager.save(rec.round_idx, tree, metadata={
                    "round_idx": rec.round_idx, "clock": float(rec.t_end)})
            return False

        tm = (dataclasses.replace(self.time_model)
              if self.time_model is not None
              else FleetTimeModel.from_clients(self.clients))
        tm.payload_bytes = engine.per_client_uplink_bytes(box["params"])
        loop = FederatedLoop(
            select_fn=select_fn, train_fn=train_fn, clients=self.clients,
            client_ids=list(self.clients),
            aggregation=self.aggregation or "sync", time_model=tm,
            mesh=self.mesh,
            availability=self.availability, faults=self.faults,
            on_round=on_round,
            snapshot_fn=lambda: (box["params"], box["state"]),
            train_one_fn=train_one_fn,
            get_model_fn=lambda: (box["params"], box["state"]),
            set_model_fn=lambda p, s: box.update(params=p, state=s),
            clock=clock)
        loop.run(rounds - start_round, start_round=start_round)
        return {"params": box["params"], "state": box["state"],
                "history": self.history, "participation": participation,
                "virtual_time": loop.clock}
