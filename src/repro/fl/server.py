"""FL servers: SmartFreeze orchestration + vanilla FedAvg (CNN testbed).

SmartFreezeServer runs the full paper pipeline end to end:
  (1) init: split model into T stages, collect local monitors' reports
      (memory, capability, one-shot output-layer gradients, local loss);
  (2) RL-CD communities from the Eq. 8 similarity matrix;
  (3) per stage: participant selection (Eq. 11-14) -> rounds of local
      training -> Eq. 1 aggregation -> pace controller observes the block
      perturbation and freezes the stage when converged;
  (4) model growth until the full model is trained.

Round execution is delegated to ``fl/engine.py``: one fused
vmap-over-clients dispatch per round plus a frozen-prefix feature cache
(declined per client via the memory-model hook below). The
deadline/straggler path keeps the sequential ``fused=False`` escape hatch.
``compress_ratio`` turns on the engine's in-graph top-k + error-feedback
uplink (see fl/compression.py); per-round payloads land in
``RoundResult.uplink_bytes``.

``selector`` accepts either the list-based ``ParticipantSelector`` or the
population-scale ``core.selector.vectorized.VectorizedSelector`` — both
implement ``fit_communities`` + ``select`` with the same contract.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import freezing_cnn as fz
from repro.core.pace import PaceController
from repro.core.selector import ParticipantSelector
from repro.core.selector.similarity import similarity_matrix
from repro.fl.client import SimClient
from repro.fl.engine import RoundEngine, weighted_avg
from repro.models.cnn import CNN
from repro.optim import Optimizer, sgd

_weighted_avg = weighted_avg  # baselines import this name


@dataclass
class RoundResult:
    round_idx: int
    stage: int
    loss: float
    test_acc: Optional[float] = None
    selected: List[int] = field(default_factory=list)
    perturbation: Optional[float] = None
    frozen: bool = False
    uplink_bytes: Optional[int] = None   # cohort uplink payload this round


def cnn_feature_cache_bytes(model: CNN, stage: int, num_samples: int,
                            image_size: int = 32) -> float:
    """Bytes to hold a client shard's frozen-prefix activations (fp32):
    the feature map at the stage boundary, one per local sample."""
    if stage <= 0:
        return 0.0
    cfg = model.cfg
    ch = cfg.stage_channels[stage - 1]
    if cfg.kind == "vgg":  # maxpool halves after every stage
        res = max(image_size // (2 ** stage), 1)
    else:  # resnet: stride-2 at each stage entry except stage 0
        res = max(image_size // (2 ** (stage - 1)), 1)
    return float(num_samples) * res * res * ch * 4.0


def cnn_stage_memory_bytes(model: CNN, stage: int, batch_size: int,
                           image_size: int = 32, *,
                           cache_samples: int = 0) -> float:
    """Eq. (4) for the CNN testbed (fp32). ``cache_samples`` is the feature
    cache hook: when a client would additionally hold its shard's frozen-
    prefix activations, the requirement grows by ``cnn_feature_cache_bytes``
    — the selector/server uses this to decline the cache on memory-poor
    clients (who fall back to recomputing the prefix)."""
    cfg = model.cfg
    res = image_size
    act = 0.0
    max_act = 0.0
    params = 0.0
    for i, (nb, ch) in enumerate(zip(cfg.stage_sizes, cfg.stage_channels)):
        r = res // (2 ** i) if cfg.kind == "vgg" else max(res // (2 ** max(i, 0)), 4)
        a = batch_size * r * r * ch * 4.0 * nb * 2  # convs per stage
        max_act = max(max_act, a / max(nb, 1))
        c_in = cfg.stage_channels[max(i - 1, 0)]
        params += nb * (9 * c_in * ch + 9 * ch * ch) * 4.0
        if i == stage:
            act = a
        if i >= stage:
            break
    opt = params * 2.0  # momentum
    total = 2 * act + params + opt + max_act
    if cache_samples:
        total += cnn_feature_cache_bytes(model, stage, cache_samples, image_size)
    return total


class SmartFreezeServer:
    def __init__(self, model: CNN, clients: List[SimClient], *,
                 optimizer_fn: Callable[[], Optimizer] = lambda: sgd(0.05),
                 clients_per_round: int = 10, local_epochs: int = 1,
                 batch_size: int = 32, rounds_per_stage: int = 60,
                 pace_kwargs: Optional[dict] = None,
                 op_kind: str = "conv", selector: Optional[ParticipantSelector] = None,
                 deadline_factor: float = 0.0, seed: int = 0,
                 fused: bool = True, cache_features: bool = True,
                 compress_ratio: Optional[float] = None):
        self.model = model
        self.clients = {c.client_id: c for c in clients}
        self.optimizer_fn = optimizer_fn
        self.k = clients_per_round
        self.local_epochs = local_epochs
        self.batch_size = batch_size
        self.rounds_per_stage = rounds_per_stage
        self.pace_kwargs = pace_kwargs or {}
        self.op_kind = op_kind
        self.selector = selector or ParticipantSelector(seed=seed)
        self.deadline_factor = deadline_factor  # >0: drop stragglers past deadline
        self.seed = seed
        self.fused = fused
        self.cache_features = cache_features
        self.compress_ratio = compress_ratio
        self.history: List[RoundResult] = []
        self._last_loss: Dict[int, float] = {}
        self.image_size = int(next(iter(self.clients.values())).data["x"].shape[1])

    # ----- bootstrap: similarity from output-layer gradients (Eq. 8) -----

    def bootstrap_similarity(self, params, state) -> np.ndarray:
        grads = {}
        for cid, c in self.clients.items():
            x = jnp.asarray(c.data["x"][:64])
            y = jnp.asarray(c.data["y"][:64])

            def head_loss(fc):
                logits, _ = self.model.apply({**params, "fc": fc}, state, x,
                                             train=False)
                lf = logits.astype(jnp.float32)
                logz = jax.scipy.special.logsumexp(lf, axis=-1)
                gold = jnp.take_along_axis(lf, y[:, None], axis=-1)[:, 0]
                return jnp.mean(logz - gold)

            g = jax.grad(head_loss)(params["fc"])
            grads[cid] = np.concatenate([np.asarray(l, np.float32).ravel()
                                         for l in jax.tree.leaves(g)])
        return similarity_matrix(grads)

    # ----- per-stage engine construction -----

    def _stage_engine(self, stage: int, frozen, bn_state) -> RoundEngine:
        model = self.model
        cached_loss = feature_fn = None
        if stage > 0:
            cached_loss = fz.cnn_cached_stage_loss_fn(model, stage,
                                                      op_kind=self.op_kind)
            feature_fn = (lambda x, _fr=frozen, _st=bn_state:
                          fz.cnn_prefix_features(model, _fr, _st, x, stage))
        return RoundEngine(
            loss_fn=fz.cnn_stage_loss_fn(model, stage, op_kind=self.op_kind),
            optimizer=self.optimizer_fn(), frozen=frozen,
            cached_loss_fn=cached_loss, feature_fn=feature_fn,
            batch_size=self.batch_size, local_epochs=self.local_epochs,
            clip_norm=10.0, fused=self.fused,
            compress_ratio=self.compress_ratio)

    def _cache_plan(self, stage: int) -> Dict[int, bool]:
        """Memory-model gate: cache only on clients whose capacity covers the
        stage requirement PLUS their shard's prefix activations."""
        if not self.cache_features or stage == 0:
            return {}
        return {cid: c.memory_bytes >= cnn_stage_memory_bytes(
                    self.model, stage, self.batch_size, self.image_size,
                    cache_samples=c.num_samples)
                for cid, c in self.clients.items()}

    # ----- main loop -----

    def run(self, params, state, *, eval_fn: Optional[Callable] = None,
            eval_every: int = 10, total_rounds: Optional[int] = None,
            schedule: Optional[List[int]] = None) -> Dict:
        """schedule: optional fixed rounds-per-stage (pace-controller ablation)."""
        model = self.model
        n_stages = len(model.cfg.stage_sizes)
        sim = self.bootstrap_similarity(params, state)
        self.selector.fit_communities(sim)
        round_idx = 0
        budget = total_rounds or self.rounds_per_stage * n_stages

        for stage in range(n_stages):
            if schedule is not None:
                plan_rounds = schedule[stage]
            else:
                # pace-adaptive budget: early freezes hand their unused rounds
                # to later stages (reserve >=1 round per remaining stage)
                remaining_stages = n_stages - stage - 1
                plan_rounds = max(budget - round_idx - remaining_stages, 1)
            pace = PaceController(**self.pace_kwargs)
            frozen, active = fz.init_cnn_stage_active(
                model, params, stage, jax.random.PRNGKey(self.seed + stage),
                op_kind=self.op_kind)
            engine = self._stage_engine(stage, frozen, state)
            cache_ok = self._cache_plan(stage)
            mem_req = cnn_stage_memory_bytes(model, stage, self.batch_size,
                                             self.image_size)

            for r in range(plan_rounds):
                if round_idx >= budget:
                    break
                # --- selection (Eq. 11-14): I_{t,i} = |D_i| * latest local loss ---
                infos = {cid: dataclasses.replace(
                    c.info(),
                    loss_sum=self._last_loss.get(cid, 1e3) * c.num_samples)
                    for cid, c in self.clients.items()}
                time_fn = lambda ci: ci.num_samples / ci.capability
                selected = self.selector.select(infos, self.k,
                                                mem_required=mem_req,
                                                stage_time_fn=time_fn)
                # --- deadline-based straggler mitigation (sequential path) ---
                straggler_round = False
                if self.deadline_factor > 0 and len(selected) > 2:
                    straggler_round = True
                    times = {cid: time_fn(infos[cid]) for cid in selected}
                    deadline = np.median(list(times.values())) * self.deadline_factor
                    kept = [cid for cid in selected if times[cid] <= deadline]
                    if len(kept) >= max(2, len(selected) // 2):
                        selected = kept
                # --- local training + Eq. 1 aggregation (fused dispatch) ---
                active, state, losses = engine.run_round(
                    self.clients, selected, active, state, round_idx,
                    use_cache=cache_ok,
                    sequential=True if straggler_round else None)
                self._last_loss.update(losses)
                # --- pace controller ---
                p = pace.observe(active.get("stages", active))
                do_freeze = pace.should_freeze() and schedule is None
                mean_loss = float(np.mean(list(losses.values())))
                rr = RoundResult(round_idx, stage, mean_loss, selected=selected,
                                 perturbation=p, frozen=do_freeze,
                                 uplink_bytes=engine.last_uplink_bytes)
                if eval_fn is not None and (round_idx % eval_every == 0 or do_freeze):
                    merged = fz.merge_cnn_params(model, params, stage, active)
                    rr.test_acc = eval_fn(merged, state, stage)
                self.history.append(rr)
                round_idx += 1
                if do_freeze:
                    break
            # --- model growth ---
            params = fz.merge_cnn_params(model, params, stage, active)
        return {"params": params, "state": state, "history": self.history,
                "rounds": round_idx}


class FedAvgServer:
    """Vanilla FL baseline: full model every round, random selection."""

    def __init__(self, model: CNN, clients: List[SimClient], *,
                 optimizer_fn=lambda: sgd(0.05), clients_per_round: int = 10,
                 local_epochs: int = 1, batch_size: int = 32,
                 mem_required: float = 0.0, seed: int = 0, fused: bool = True,
                 compress_ratio: Optional[float] = None):
        self.model = model
        self.clients = {c.client_id: c for c in clients}
        self.optimizer_fn = optimizer_fn
        self.k = clients_per_round
        self.local_epochs = local_epochs
        self.batch_size = batch_size
        self.mem_required = mem_required
        self.seed = seed
        self.fused = fused
        self.compress_ratio = compress_ratio
        self.history: List[RoundResult] = []

    def run(self, params, state, *, rounds: int, eval_fn=None, eval_every=10):
        model = self.model
        n_stages = len(model.cfg.stage_sizes)

        def full_loss(p, frozen_unused, st, batch):
            return model.loss(p, st, batch, train=True)

        engine = RoundEngine(loss_fn=full_loss, optimizer=self.optimizer_fn(),
                             batch_size=self.batch_size,
                             local_epochs=self.local_epochs,
                             clip_norm=10.0, fused=self.fused,
                             compress_ratio=self.compress_ratio)
        rng = np.random.RandomState(self.seed)
        eligible = [cid for cid, c in self.clients.items()
                    if c.memory_bytes >= self.mem_required]
        for r in range(rounds):
            if not eligible:
                break
            sel = list(rng.choice(eligible, size=min(self.k, len(eligible)),
                                  replace=False))
            params, state, losses = engine.run_round(
                self.clients, sel, params, state, r)
            rr = RoundResult(r, n_stages - 1,
                             float(np.mean(list(losses.values()))), selected=sel,
                             uplink_bytes=engine.last_uplink_bytes)
            if eval_fn is not None and r % eval_every == 0:
                rr.test_acc = eval_fn(params, state, n_stages - 1)
            self.history.append(rr)
        return {"params": params, "state": state, "history": self.history,
                "participation": len(eligible) / len(self.clients)}
