"""Precision tiers for the frozen-prefix activation cache (+ bf16 compute).

SmartFreeze's headline claim is the memory one (Eq. 4, up to 82% footprint
reduction), and once later stages train over cached frozen-prefix features
(fl/engine.py), the feature tensor becomes the dominant per-client memory
term. This module shrinks it:

  tier "f32"   4 bytes/elem — the PR-1 behavior, exact.
  tier "fp16"  2 bytes/elem — plain dtype narrowing, no extra state.
  tier "int8"  1 byte/elem  — per-(sample, channel) symmetric quantization:
               q = clip(round(x / s), -127, 127), s = amax / 127 computed
               over each sample's interior axes per channel, so a client
               shard [N, H, W, C] stores int8 values plus f32 scales
               [N, 1, 1, C] (LM features [N, S, D] store scales [N, 1, D]).

Dequantization is FUSED INTO THE CACHED-CONSUMER LOSS via
``make_tiered_loss``: the compiled round receives the int8 values + scales
and multiplies them back inside the jitted dispatch, so the f32 feature
tensor never materializes outside the compiled round (XLA fuses the
broadcast-multiply into the first consumer). The f32 round-trip error is
elementwise bounded by s/2 = amax/254 per (sample, channel) group
(property-tested in tests/test_quant.py).

``make_input_cast_loss`` is the bf16 half of the memory story: it casts the
batch's floating leaves to a compute dtype inside the graph, pairing with
``make_fused_round(compute_dtype=...)``'s f32-master-weights loop so local
training runs bf16 forward/backward while optimizer state, Eq. 1
aggregation, and the parameter stream stay f32.

The admission ladder (which tier a client is granted) lives with the
memory model: ``core.memory_model.cache_tier_ladder`` on the host and
``core.selector.vectorized.assign_cache_tiers`` as the O(N) population
kernel. Scale arrays ride the same per-sample indexing as the data
(``x_scale`` is gathered by the identical minibatch plan as ``x``), which
is what lets both the fused and sequential round paths consume tiered
caches without special-casing.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Tier ladder in ADMISSION ORDER: the server tries the most exact tier
# first and degrades until the client's memory fits. The table itself lives
# with the memory model (core/ must not import fl/) — re-exported here as
# the quantization API's vocabulary.
from repro.core.memory_model import (CACHE_TIER_DTYPES as TIER_DTYPES,  # noqa: E402
                                     CACHE_TIERS)


def normalize_tier(tier) -> Optional[str]:
    """Canonicalize a cache-plan entry: legacy ``True`` means the f32 tier
    (pre-tier servers passed booleans), falsy means "no cache"."""
    if tier is None or tier is False or (isinstance(tier, np.bool_) and not tier):
        return None
    if tier is True or isinstance(tier, np.bool_):
        return "f32"
    if tier in CACHE_TIERS:
        return str(tier)
    raise ValueError(f"unknown cache tier {tier!r}; expected one of "
                     f"{CACHE_TIERS} (or True/False)")


def _group_axes(ndim: int) -> Tuple[int, ...]:
    """Axes reduced per quantization group: interior axes for >=3-D
    (per-sample, per-channel), everything but the sample axis for 2-D."""
    if ndim < 2:
        raise ValueError(f"feature arrays must be >=2-D, got ndim={ndim}")
    return tuple(range(1, ndim - 1)) if ndim >= 3 else (1,)


@jax.jit
def quantize_int8(x):
    """Per-(sample, channel) symmetric int8 quantization.

    Returns ``(q int8, scale f32)`` with ``scale`` keeping reduced axes as
    size-1 dims, so ``q.astype(f32) * scale`` broadcasts back and both
    arrays index identically along the sample axis (minibatch gathers need
    no special case). All-zero groups get scale 1.0 (q is 0 there anyway).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=_group_axes(x.ndim), keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


@jax.jit
def dequantize_int8(q, scale):
    """Inverse of ``quantize_int8`` (f32). Inside a compiled loss this is a
    fused broadcast-multiply — the dense f32 tensor exists only as an XLA
    fusion intermediate, never as a stored buffer."""
    return q.astype(jnp.float32) * scale


class EncodedFeatures(NamedTuple):
    """One client's cached prefix features at some tier (host-resident)."""
    tier: str
    values: np.ndarray                  # f32 | f16 | int8, sample-leading
    scale: Optional[np.ndarray] = None  # int8 only: f32, broadcastable

    @property
    def nbytes(self) -> int:
        return self.values.nbytes + (self.scale.nbytes
                                     if self.scale is not None else 0)


def encode_features(x: np.ndarray, tier: str) -> EncodedFeatures:
    """Quantize-on-write: features leave the frozen prefix once and are
    stored at the admitted tier.

    The tier ladder walks most-exact-first (f32 -> fp16 -> int8, see
    ``CACHE_TIERS``); each step shrinks the stored bytes (int8's f32 scale
    vectors amortize over the interior axes, so real feature maps approach
    4x) and int8 bounds the round-trip error by amax/254 per
    (sample, channel) group:

    >>> import numpy as np
    >>> x = np.linspace(-1.0, 1.0, 8, dtype=np.float32).reshape(2, 4)
    >>> [encode_features(x, t).nbytes for t in CACHE_TIERS]  # f32 fp16 int8
    [32, 16, 16]
    >>> enc = encode_features(x, "int8")   # int8 values + [2, 1] f32 scales
    >>> (enc.values.dtype.name, enc.scale.shape)
    ('int8', (2, 1))
    >>> err = np.abs(decode_features(enc) - x)
    >>> bool((err <= np.abs(x).max(axis=1, keepdims=True) / 254 + 1e-7).all())
    True
    """
    if tier == "f32":
        return EncodedFeatures("f32", np.asarray(x, np.float32))
    if tier == "fp16":
        return EncodedFeatures("fp16", np.asarray(x, np.float16))
    if tier == "int8":
        q, s = quantize_int8(jnp.asarray(x))
        return EncodedFeatures("int8", np.asarray(q), np.asarray(s))
    raise ValueError(f"unknown cache tier {tier!r}")


def decode_features(enc: EncodedFeatures) -> np.ndarray:
    """Host-side reference inverse (tests / debugging; the training path
    dequantizes in-graph via ``make_tiered_loss``)."""
    if enc.tier == "int8":
        return np.asarray(dequantize_int8(jnp.asarray(enc.values),
                                          jnp.asarray(enc.scale)))
    return np.asarray(enc.values, np.float32)


def feature_batch_arrays(enc: EncodedFeatures) -> Dict[str, np.ndarray]:
    """The data-dict entries a cached client contributes: ``x`` at the
    stored dtype, plus ``x_scale`` for int8. Both are sample-leading, so
    the round paths gather them with the ordinary minibatch index plan."""
    out = {"x": enc.values}
    if enc.scale is not None:
        out["x_scale"] = enc.scale
    return out


def tiered_matmul(x, x_scale, w, *, use_pallas: bool = False):
    """The leading GEMM of a quant-aware cached consumer: computes
    ``dequant(x) @ w`` with the per-(sample, channel) scales applied
    in-register (Pallas, kernels/dequant_matmul.py) or via the XLA
    broadcast-multiply reference. ``x``: [N, D] int8 (or float) cache
    features; ``x_scale``: broadcastable scales ([N, 1] from the 2-D
    quantizer, or None for float tiers); ``w``: [D, H]. f32 out.

    Differentiable wrt ``w`` (and ``x_scale``) on both paths — the Pallas
    op carries a custom_vjp through the XLA reference, so cached local
    training backprops exactly."""
    if x_scale is None:
        x_scale = jnp.ones((), jnp.float32)
    if use_pallas:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.dequant_matmul(x, x_scale, w)
    xf = x.astype(jnp.float32) * jnp.asarray(x_scale).astype(jnp.float32)
    return jax.lax.dot_general(xf, w.astype(jnp.float32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def make_tiered_loss(loss_fn, tier: Optional[str],
                     compute_dtype: Optional[str] = None,
                     use_pallas: bool = False):
    """Wrap a cached-consumer loss so the in-graph batch carries encoded
    features: int8 dequantizes (written inline so XLA fuses the broadcast
    multiply straight into the first consumer), fp16 upcasts; f32/None is
    the identity. The wrapper pops ``x_scale`` so downstream losses see the
    same batch keys as the f32 path. With ``compute_dtype`` set, the
    decoded features land in that dtype (the dequant arithmetic itself
    stays f32 so the int8 scales are never degraded to bf16).

    Quant-aware consumers (``loss_fn.consumes_quantized`` truthy — losses
    whose first op is a GEMM they route through ``tiered_matmul``) skip the
    materializing dequant on the int8 tier: the batch keeps ``x`` int8 and
    ``x_scale``, and with ``use_pallas`` the loss's ``tiered_matmul`` call
    fuses the dequant into the GEMM in-register. Conv-first consumers (the
    CNN cached stages) have no leading GEMM, so they always take the
    materializing path — that dispatch rule is documented in
    docs/ARCHITECTURE.md."""
    tier = normalize_tier(tier)
    if tier in (None, "f32"):
        return loss_fn
    out_dt = jnp.dtype(compute_dtype) if compute_dtype else jnp.float32

    if tier == "int8" and getattr(loss_fn, "consumes_quantized", False):
        def quant_aware(params, frozen, state, batch):
            b = dict(batch)
            b["use_pallas"] = use_pallas
            return loss_fn(params, frozen, state, b)
        quant_aware.consumes_quantized = True
        return quant_aware

    def tiered(params, frozen, state, batch):
        b = dict(batch)
        if tier == "int8":
            b["x"] = (b["x"].astype(jnp.float32)
                      * b.pop("x_scale").astype(jnp.float32)).astype(out_dt)
        else:  # fp16
            b["x"] = b["x"].astype(out_dt)
        return loss_fn(params, frozen, state, b)

    return tiered


def make_input_cast_loss(loss_fn, compute_dtype: Optional[str]):
    """Cast the batch's floating leaves to ``compute_dtype`` inside the
    graph (bf16 local training) — EXCEPT ``*_scale`` keys: quantization
    scales must stay f32 so int8 dequantization is never degraded to bf16
    (``make_tiered_loss`` pops them and dequantizes in f32 itself). The
    single source of the mixed-precision batch-cast rule, shared by the
    fused and sequential engine paths."""
    if compute_dtype is None:
        return loss_fn
    dt = jnp.dtype(compute_dtype)

    def cast(params, frozen, state, batch):
        b = {k: (v.astype(dt)
                 if (jnp.issubdtype(v.dtype, jnp.floating)
                     and not k.endswith("_scale")) else v)
             for k, v in batch.items()}
        return loss_fn(params, frozen, state, b)

    return cast


def cast_floating(tree, dtype):
    """Cast a pytree's floating leaves (mixed-precision params/frozen cast;
    integer leaves — e.g. step counters — pass through)."""
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
