"""Simulated FL clients with heterogeneous memory / compute (paper §V-A).

Each client owns a private shard of the dataset, a memory capacity drawn from
the paper's two contention scenarios, and a runtime capability c_i. The local
monitor reports (memory, capability, output-layer gradient (once), local
loss) to the server — nothing else leaves the device.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selector.selection import ClientInfo

# Paper memory scenarios [3]: available RAM (GiB) under high / low contention
HIGH_CONTENTION_GB = (0.5, 0.75, 1.0, 1.5, 2.0)
LOW_CONTENTION_GB = (2.0, 3.0, 4.0, 6.0, 8.0)
# Heterogeneous device tiers (relative FLOP/s; RPi ... Jetson TX2 ... phone)
CAPABILITY_TIERS = (0.3e9, 1.0e9, 2.5e9, 5.0e9, 10.0e9)


def batch_index_plan(n: int, batch_size: int, epochs: int, seed: int
                     ) -> List[np.ndarray]:
    """The exact minibatch index sequence a client runs locally: per-epoch
    permutation, drop-last. Shared by the sequential generator below AND the
    fused round engine's host-side batch stacking, so both execution paths
    consume bit-identical data for a given (client, round) seed."""
    rng = np.random.RandomState(seed)
    plan = []
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            plan.append(order[i:i + batch_size])
    return plan


@dataclass
class SimClient:
    client_id: int
    data: Dict[str, np.ndarray]
    memory_bytes: float
    capability: float
    seed: int = 0
    link_rate: float = float("inf")   # uplink bytes/s (inf = free network)
    _head_grad: Optional[np.ndarray] = None

    @property
    def num_samples(self) -> int:
        return len(self.data["y"]) if "y" in self.data else len(self.data["labels"])

    def round_seed(self, round_idx: int) -> int:
        return self.seed * 99991 + round_idx

    def batches(self, batch_size: int, epochs: int, seed: int):
        for idx in batch_index_plan(self.num_samples, batch_size, epochs, seed):
            yield {k: v[idx] for k, v in self.data.items()}

    def local_train(self, step_fn: Callable, active, frozen, bn_state, opt_state,
                    *, batch_size: int, epochs: int, round_idx: int):
        """Runs the jitted stage step over local minibatches.

        Returns (active, bn_state, mean_loss, num_batches)."""
        losses = []
        for batch in self.batches(batch_size, epochs, self.round_seed(round_idx)):
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            active, bn_state, opt_state, loss = step_fn(active, frozen, bn_state,
                                                        opt_state, jb)
            losses.append(float(loss))
        mean_loss = float(np.mean(losses)) if losses else 0.0
        return active, bn_state, mean_loss, len(losses)

    def info(self) -> ClientInfo:
        return ClientInfo(self.client_id, self.memory_bytes, self.capability,
                          self.num_samples)

    def label_histogram(self, num_classes: int) -> np.ndarray:
        """Local label counts — the raw material for the population-scale
        sketch-similarity path (core/selector/similarity.py). Reporting a
        hashed sketch of this histogram costs O(sketch_dim) uplink, vs the
        output-layer gradient's O(|head|)."""
        y = self.data["y"] if "y" in self.data else self.data["labels"]
        return np.bincount(np.asarray(y).ravel(), minlength=num_classes)


def fleet_label_histograms(clients: List[SimClient], num_classes: int
                           ) -> np.ndarray:
    """[N, num_classes] label histograms in ascending-client-id order —
    feed to ``core.selector.rlcd.sketch_communities`` /
    ``VectorizedSelector.fit_communities_sketch``."""
    return np.stack([c.label_histogram(num_classes)
                     for c in sorted(clients, key=lambda c: c.client_id)])


def fleet_population(clients: List[SimClient], *, community_id=None,
                     n_communities: int = 1):
    """Snapshot a simulated fleet into a device-resident
    ``ClientPopulation`` (structure-of-arrays) for the vectorized selector."""
    from repro.core.selector.vectorized import ClientPopulation

    return ClientPopulation.from_infos(
        [c.info() for c in sorted(clients, key=lambda c: c.client_id)],
        community_id=community_id, n_communities=n_communities)


def make_client_fleet(data: Dict[str, np.ndarray], parts: List[np.ndarray], *,
                      scenario: str = "low", seed: int = 0,
                      link_rate_pool: Optional[List[float]] = None
                      ) -> List[SimClient]:
    """Build a heterogeneous fleet from a dataset + index partition.

    ``link_rate_pool``: optional uplink rates (bytes/s) drawn per client —
    feeds ``fl.sim.FleetTimeModel`` so compressed-uplink payloads translate
    into heterogeneous communication time. Default: free network (inf)."""
    rng = np.random.RandomState(seed)
    mem_pool = HIGH_CONTENTION_GB if scenario == "high" else LOW_CONTENTION_GB
    clients = []
    for cid, idx in enumerate(parts):
        local = {k: v[idx] for k, v in data.items()}
        clients.append(SimClient(
            client_id=cid, data=local,
            memory_bytes=float(rng.choice(mem_pool)) * 2**30,
            capability=float(rng.choice(CAPABILITY_TIERS)),
            seed=seed + cid,
            link_rate=(float(rng.choice(link_rate_pool))
                       if link_rate_pool else float("inf"))))
    return clients
