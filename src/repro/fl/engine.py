"""Fused federated round engine: one compiled dispatch per cohort, plus a
frozen-prefix activation cache.

The seed simulator executed each round as ``K clients x E epochs x B
minibatches`` separate jitted calls, each with a host->device batch copy and
a blocking ``float(loss)`` sync, and re-ran the frozen prefix's forward on
every one of them. This module collapses both costs:

  * ``make_fused_round`` stacks the K selected clients' minibatch sequences
    into a leading client axis and runs the whole round as ONE
    ``jax.jit(vmap(lax.scan(local_sgd_step)))`` with the Eq. 1
    dataset-weighted aggregation inside the compiled function. Clients with
    fewer local batches than the cohort maximum are masked per scan step
    (updates/losses suppressed once a client's plan is exhausted), so the
    fused result matches the sequential per-client loop exactly for fixed
    seeds.
  * ``RoundEngine`` adds the frozen-prefix feature cache: when a stage
    begins, each participating client runs the frozen prefix ONCE over its
    shard (eval mode, behind the ``stop_gradient`` boundary of
    ``cnn_stage_forward``/``stage_forward``) and local training thereafter
    consumes cached features — progressive training's later stages become
    shallow-model training (NeuLite arXiv:2408.10826, ProFL
    arXiv:2404.13349). The cache is invalidated on stage growth and is
    opt-in per client: the server checks the memory model's cache hook
    (``cnn_stage_memory_bytes(..., cache_samples=n)`` /
    ``stage_memory_bytes(..., cache_tokens=n)``) and declines it on
    memory-poor clients, who silently fall back to full recompute.

``fused=False`` is the escape hatch kept for the deadline/straggler path:
it runs the seed-identical sequential per-client loop (still optionally
consuming cached features).

The LM backend's ``make_fed_round_step`` (core/freezing.py) already fuses
pods inside one jit; ``make_lm_cached_fed_round_step`` below is its
cache-consuming sibling with ``donate_argnums`` on (active, opt_state).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import (CLIENT_AXIS, client_axis_size, replicate,
                                 shard_cohort)
from repro.fl.client import SimClient, batch_index_plan
from repro.fl.faults import (CORRUPT_KINDS, FAULT_CODE, apply_fault_to_update,
                             corrupt_codes)
from repro.fl.compression import (ingraph_compress_leaf,
                                  ingraph_sparse_aggregate, ingraph_topk,
                                  topk_keep)
from repro.fl.quant import (CACHE_TIERS, EncodedFeatures, cast_floating,
                            encode_features, feature_batch_arrays,
                            make_input_cast_loss, make_tiered_loss,
                            normalize_tier)
from repro.optim import Optimizer, apply_updates, clip_by_global_norm

LossFn = Callable[[Any, Any, Any, Dict], Tuple[jnp.ndarray, Any]]
#   loss_fn(params, frozen, state, batch) -> (loss, new_state)


# ---------------------------------------------------------------------------
# Host-side aggregation (shared by servers/baselines; Eq. 1)
# ---------------------------------------------------------------------------


# The mul and add phases are SEPARATE jits on purpose: inside one compiled
# program XLA clones each product into the consumer fusion and the CPU
# emitter contracts mul+add into an FMA (optimization_barrier does not
# survive the duplication), which diverges from the seed's op-per-dispatch
# execution by 1 ulp. Muls alone and adds alone are bitwise exact, so the
# two-dispatch split keeps the seed fold's values while replacing K x leaves
# host-scalar dispatches with 2 (regression-tested in tests/test_quant.py).


def _weighted_avg_products(trees: Tuple, w):
    return tuple(jax.tree.map(lambda x: x.astype(jnp.float32) * w[i], t)
                 for i, t in enumerate(trees))


def _weighted_avg_sum(prods: Tuple, ref):
    out = prods[0]
    for p in prods[1:]:
        out = jax.tree.map(jnp.add, out, p)  # left fold, no reassociation
    return jax.tree.map(lambda a, r: a.astype(r.dtype), out, ref)


_wavg_products_jit = jax.jit(_weighted_avg_products)
_wavg_sum_jit = jax.jit(_weighted_avg_sum)


def weighted_avg(trees: Sequence, w: np.ndarray):
    """Dataset-weighted parameter average over a list of pytrees (Eq. 1) as
    a jitted weighted sum — two dispatches per call (products, then the
    left-fold accumulation), bit-identical to the seed's sequential
    ``tree.map`` loop; retraces only per (cohort size, tree structure)."""
    trees = tuple(trees)
    prods = _wavg_products_jit(trees, jnp.asarray(np.asarray(w, np.float32)))
    return _wavg_sum_jit(prods, trees[0])


# ---------------------------------------------------------------------------
# Update screening + robust aggregation (ISSUE 7: in-graph defenses)
# ---------------------------------------------------------------------------


AGGREGATORS = ("mean", "trimmed_mean", "coord_median")


def _apply_fault_codes(params, out_p, losses, codes, amplify):
    """In-graph delta-space corruption over the stacked client axis: row i
    of every leaf gets its update delta NaN'd / Inf'd / negated / scaled
    per ``codes[i]`` (0 = clean; fl/faults.FAULT_CODE). NaN/Inf rows also
    poison the reported per-client loss, mirroring what genuinely
    non-finite local gradients would do."""
    def leaf(p0, pk):
        p0f = p0.astype(jnp.float32)
        d = pk.astype(jnp.float32) - p0f[None]
        c = codes.reshape((-1,) + (1,) * (d.ndim - 1))
        d = d * jnp.where(c == FAULT_CODE["signflip"], -1.0,
                          jnp.where(c == FAULT_CODE["amplify"],
                                    jnp.float32(amplify), 1.0))
        d = jnp.where(c == FAULT_CODE["nan"], jnp.float32(jnp.nan), d)
        d = jnp.where(c == FAULT_CODE["inf"], jnp.float32(jnp.inf), d)
        # clean rows (code 0) keep their EXACT trained value — p0 + (pk -
        # p0) re-rounds, which would break zero-code bit-identity
        out = jnp.where(c == 0, pk.astype(jnp.float32), p0f[None] + d)
        return out.astype(pk.dtype)

    out_p = jax.tree.map(leaf, params, out_p)
    bad = ((codes == FAULT_CODE["nan"]) | (codes == FAULT_CODE["inf"]))
    return out_p, jnp.where(bad, jnp.float32(jnp.nan), losses)


def _delta_norms(params, out_p):
    """[K] f32 global L2 norms of each cohort row's param delta (NaN/Inf
    anywhere in a row surfaces as a non-finite norm)."""
    sq = None
    for p0, pk in zip(jax.tree.leaves(params), jax.tree.leaves(out_p)):
        d = pk.astype(jnp.float32) - p0.astype(jnp.float32)[None]
        s = jnp.sum(d * d, axis=tuple(range(1, d.ndim)))
        sq = s if sq is None else sq + s
    return jnp.sqrt(sq)


def _delta_norm_one(params, p_i):
    """Scalar f32 global L2 norm of ONE client's param delta — the
    per-client twin of ``_delta_norms`` for the unrolled / sequential
    paths (same op chain per row)."""
    sq = None
    for p0, pk in zip(jax.tree.leaves(params), jax.tree.leaves(p_i)):
        d = pk.astype(jnp.float32) - p0.astype(jnp.float32)
        s = jnp.sum(d * d)
        sq = s if sq is None else sq + s
    return jnp.sqrt(sq)


def _lower_median(sorted_vals, n_valid):
    """Lower median of the first ``n_valid`` entries of an ascending-sorted
    vector whose invalid tail is +inf (inf when nothing is valid)."""
    return sorted_vals[jnp.maximum(n_valid - 1, 0) // 2]


def _keep_mask(norms, losses, weights, mult):
    """Zero-weight screening mask (applied BEFORE the Eq. 1 normalizer):
    drop rows with a non-finite loss or delta, and rows whose delta norm
    exceeds ``mult`` x the cohort's (lower) median norm. Inert/padded rows
    (weight 0) are excluded from the median and never kept. With every row
    clean the mask is all-true and ``where(mask, w, 0)`` is bitwise ``w`` —
    the zero-fault bit-identity contract."""
    finite = jnp.isfinite(norms) & jnp.isfinite(losses)
    valid = finite & (weights > 0)
    n_v = jnp.sum(valid.astype(jnp.int32))
    med = _lower_median(jnp.sort(jnp.where(valid, norms, jnp.inf)), n_v)
    outlier = jnp.isfinite(med) & (norms > mult * med + 1e-6)
    return valid & ~outlier


def _robust_leaf(x, keep, n_valid, aggregator, trim_beta):
    """Per-coordinate robust combine of a stacked [K, ...] leaf over the
    kept rows: ``coord_median`` (average of the two middle order
    statistics) or ``trimmed_mean`` (drop floor(beta * n) from each end,
    unweighted mean of the band). Masked rows sort to +inf and the order
    statistics index only the valid prefix, so zero-weight masking composes
    exactly as it does for the weighted mean."""
    xf = x.astype(jnp.float32)
    K = x.shape[0]
    kcol = keep.reshape((K,) + (1,) * (x.ndim - 1))
    s = jnp.sort(jnp.where(kcol, xf, jnp.inf), axis=0)
    if aggregator == "coord_median":
        lo = jnp.maximum(n_valid - 1, 0) // 2
        hi = jnp.maximum(n_valid - 1, 0) - lo
        out = (jnp.take(s, lo, axis=0) + jnp.take(s, hi, axis=0)) * 0.5
    else:  # trimmed_mean
        t = jnp.floor(trim_beta * n_valid.astype(jnp.float32)).astype(jnp.int32)
        t = jnp.minimum(t, jnp.maximum(n_valid - 1, 0) // 2)
        idx = jnp.arange(K).reshape((K,) + (1,) * (x.ndim - 1))
        in_band = (idx >= t) & (idx < n_valid - t)
        out = (jnp.sum(jnp.where(in_band, s, 0.0), axis=0)
               / jnp.maximum(n_valid - 2 * t, 1).astype(jnp.float32))
    return out.astype(x.dtype)


def _recombine_kept(params, state, out_p, out_st, k_host, weights):
    """Host-side Eq. 1 over the KEPT rows of a screened fused round — the
    same ``weighted_avg`` combine the sequential path uses. Zero-weight
    masking inside the compiled aggregate would not be NaN-safe (0 x NaN =
    NaN still poisons a fold), so excluded rows are dropped before the
    combine. Only reached on rounds where screening actually fired (which
    voids the bit-identity contract anyway); with every row screened out
    the round is a no-op."""
    if not k_host.any():
        return params, state
    idx = np.nonzero(k_host)[0]
    p_host = jax.tree.map(lambda x: np.asarray(x), out_p)
    s_host = jax.tree.map(lambda x: np.asarray(x), out_st)
    kept_p = [jax.tree.map(lambda x: x[i], p_host) for i in idx]
    kept_s = [jax.tree.map(lambda x: x[i], s_host) for i in idx]
    w = np.asarray(weights, np.float64)[idx]
    w /= w.sum()
    return weighted_avg(kept_p, w), weighted_avg(kept_s, w)


# ---------------------------------------------------------------------------
# Fused multi-client round (tentpole #2)
# ---------------------------------------------------------------------------


def make_fused_round(loss_fn: LossFn, optimizer: Optimizer, *,
                     clip_norm: float = 10.0, unroll: Optional[bool] = None,
                     compress_ratio: Optional[float] = None,
                     compute_dtype: Optional[str] = None,
                     mesh=None, screen: bool = False,
                     screen_norm_mult: float = 8.0,
                     aggregator: str = "mean", trim_beta: float = 0.2,
                     inject_faults: bool = False,
                     fault_amplify: float = 50.0,
                     use_pallas: bool = False):
    """Build the single-dispatch round function.

    A minimal round — two clients, one local SGD step each on a scalar
    least-squares loss — showing the calling convention (cohort-stacked
    batches, per-client live-step counts, Eq. 1 weights):

    >>> import jax.numpy as jnp
    >>> from repro.optim import sgd
    >>> def loss_fn(params, frozen, state, batch):
    ...     err = params["w"] * batch["x"] - batch["y"]
    ...     return jnp.mean(err ** 2), state
    >>> round_fn = make_fused_round(loss_fn, sgd(0.1))
    >>> params = {"w": jnp.ones(())}
    >>> batches = {"x": jnp.ones((2, 1, 4)),   # [K=2 clients, nb=1, batch=4]
    ...            "y": jnp.zeros((2, 1, 4))}
    >>> p, st, losses = round_fn(params, {}, {}, batches,
    ...                          jnp.ones(2, jnp.int32), jnp.ones(2))
    >>> losses.shape                  # per-client mean loss
    (2,)
    >>> round(float(p["w"]), 3)       # w <- 1 - 0.1 * d/dw mean((w*x)^2)
    0.8

    Returned callable signature::

        round_fn(params, frozen, state, batches, nb_live, weights)
          params:  cohort-shared start params (no client dim)
          frozen:  replicated frozen tree (or a placeholder when unused)
          state:   cohort-shared mutable state (BN stats; {} when unused)
          batches: pytree with leading dims [K, nb, batch, ...]
          nb_live: [K] int32 — client i's real batch count (steps >= nb_live
                   are padding and masked out)
          weights: [K] float — Eq. 1 aggregation weights (|D_i|)
          -> (agg_params, agg_state, per_client_mean_loss [K])

    With ``compress_ratio`` set, the uplink is top-k sparsified INSIDE the
    same dispatch (``lax.top_k`` per leaf on each client's param delta,
    error feedback added before selection, server aggregation as a
    scatter-add over the sparse (indices, values) — zero host decompress)::

        round_fn(params, frozen, state, batches, nb_live, weights, residuals)
          residuals: params-shaped pytree of [K, leaf_size] f32 — each
                     client's carried error-feedback state
          -> (agg_params, agg_state, per_client_mean_loss [K], new_residuals)

    ``compress_ratio=1.0`` still routes through the sparse path and must
    reproduce the dense Eq. 1 aggregate (allclose; property-tested).

    Lowering strategy (``unroll``, default auto by backend):
      * accelerators: ``vmap(lax.scan(step))`` over the client axis — XLA
        lowers the per-client-weight contractions to efficient batched
        matmuls/convs and the K local trainings run data-parallel.
      * CPU (``unroll=True``): identical semantics, but the client axis is a
        statically-unrolled loop and the local steps use ``scan(unroll=True)``
        — the CPU backend executes convolutions inside ``while`` bodies on a
        ~4x slower single-threaded path and has no fast batched-weight conv,
        so the vmap form LOSES to the host loop there (measured).
      Both forms are one jit dispatch with the Eq. 1 weighted aggregation
      inside the compiled function and ONE host sync per round.

    The stacked ``batches`` buffer is donated on accelerators — it is
    rebuilt from host data every round. Params/state are NOT donated because
    a round may split into several fused cohorts (cached vs recompute
    groups) that share them.

    ``compute_dtype`` (e.g. ``"bfloat16"``) switches local training to
    mixed precision: each SGD step casts a throwaway copy of the params
    (and the replicated frozen tree + the batch's floating leaves, minus
    ``*_scale`` quantization scales) to the compute dtype for the
    forward/backward, then casts the gradients back — the carried params
    stay f32 master weights, the optimizer state is built over (and
    updated in) f32, and the Eq. 1 aggregation is the unchanged f32 sum.
    Default ``None`` is the exact seed-identical f32 loop.

    ``mesh`` (a ``launch.mesh.make_client_mesh`` mesh with a ``"clients"``
    axis of size > 1) switches to the SHARDED cohort path: the vmapped
    per-client local training is ``shard_map``-ped over the client axis —
    each device trains its cohort shard against replicated params/frozen/
    state, the Eq. 1 weight normalization and the weighted parameter/state
    sums become per-shard partial reductions joined by ONE cross-device
    ``psum`` per round (two for the compressed path: params + BN state),
    and per-client losses come back partitioned along the same axis. The
    caller pads the cohort to a multiple of the axis size with
    ``nb_live=0`` / ``weight=0`` rows (``RoundEngine`` does this), which
    contribute exactly zero to every reduction. Semantics are unchanged —
    the sharded aggregate equals the single-device vmap form up to f32
    summation order (allclose, property-tested); mesh ``None`` or a
    size-1 client axis returns the bit-identical single-device callable.

    ``screen=True`` (ISSUE 7) computes an in-graph update screen alongside
    the round: rows with a non-finite loss/delta or a delta norm past
    ``screen_norm_mult`` x the cohort median are flagged in a trailing
    ``keep`` [K] bool output, and the defended callable returns
    ``(agg_params, agg_state, losses, keep)``. While every live row passes,
    the aggregate comes from the UNTOUCHED legacy graph and is BIT-identical
    to ``screen=False`` (regression-tested; on the unrolled CPU form this
    costs a second local-training dispatch — the legacy fold's XLA
    fusion/FMA lowering shifts by 1 ulp if its graph gains any output, so
    the screen probe must be a separate jit). When screening fires, the
    kept rows are recombined host-side via ``weighted_avg`` — NaN-safe,
    unlike zero-weight masking (0 x NaN = NaN) — and the mesh path gathers
    the median statistic with one ``all_gather`` so the verdict matches the
    single-device screen. If every live row screens out, the round is a
    no-op (params/state returned unchanged).

    ``aggregator`` swaps the Eq. 1 weighted mean for a robust,
    unweighted per-coordinate combine over the kept rows:
    ``"trimmed_mean"`` (drop ``floor(trim_beta * n)`` order statistics from
    each end) or ``"coord_median"``. Robust aggregators require the full
    cohort on one device (``mesh=None``).

    ``inject_faults=True`` adds an optional trailing ``fault_codes`` [K]
    int32 argument (``fl/faults.FAULT_CODE``; pass ``None`` for a clean
    round) that corrupts the per-client deltas IN-GRAPH after local
    training, so injected corruption hits the screen exactly like a real
    byzantine update.

    ``use_pallas=True`` routes the compressed-uplink Eq. 1 fold through the
    Pallas cohort scatter-add kernel (kernels/sparse_agg.py): the vmap form
    swaps the per-leaf XLA scatter for the single-launch fold, and the
    unrolled CPU form collects every client's (idx, vals) rows and folds
    the cohort in ONE kernel at the end of the round instead of K
    incremental scatter dispatches. Selection math (top-k, error feedback)
    is shared, so residual state is identical on both paths; the default
    ``False`` keeps the exact pre-kernel XLA graphs (bit-compat escape
    hatch). Not composed with ``mesh`` (the sharded fold joins per-device
    partials via psum — a per-shard kernel would buy nothing and the
    combination is untested; raises ValueError).
    """
    if aggregator not in AGGREGATORS:
        raise ValueError(f"unknown aggregator {aggregator!r}; "
                         f"choose from {AGGREGATORS}")
    defended = screen or inject_faults or aggregator != "mean"
    if compress_ratio is not None and defended:
        raise ValueError(
            "screening / robust aggregation / fault injection do not "
            "compose with the compressed uplink (error-feedback residuals "
            "would carry the corrupted signal forward); use "
            "compress_ratio=None")
    n_shards = client_axis_size(mesh)
    if n_shards > 1 and aggregator != "mean":
        raise ValueError("robust aggregators need the full cohort on one "
                         "device; use mesh=None with aggregator=" +
                         repr(aggregator))
    if use_pallas and n_shards > 1:
        raise ValueError("use_pallas does not compose with a sharded client "
                         "mesh; use mesh=None (the sharded fold is psum-"
                         "joined per shard)")
    if unroll is None:
        unroll = n_shards <= 1 and jax.default_backend() == "cpu"
    if n_shards > 1:
        # the sharded path is the vmap form per shard — the CPU host loop
        # cannot be partitioned by shard_map
        unroll = False
    cdt = jnp.dtype(compute_dtype) if compute_dtype is not None else None
    loss_fn = make_input_cast_loss(loss_fn, compute_dtype)

    def local_train(params, frozen, state, batches, nb):
        opt_state = optimizer.init(params)  # f32 master-weight state
        if cdt is not None:
            frozen = cast_floating(frozen, cdt)

        def one(carry, batch):
            p, st, ost, t, lsum = carry
            if cdt is None:
                (loss, st2), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    p, frozen, st, batch)
            else:
                (loss, st2), grads = jax.value_and_grad(
                    lambda pc: loss_fn(pc, frozen, st, batch),
                    has_aux=True)(cast_floating(p, cdt))
                grads = jax.tree.map(lambda g, m: g.astype(m.dtype), grads, p)
                st2 = jax.tree.map(lambda a, m: a.astype(m.dtype), st2, st)
                loss = loss.astype(jnp.float32)
            grads, _ = clip_by_global_norm(grads, clip_norm)
            ups, ost2 = optimizer.update(grads, ost, p)
            p2 = apply_updates(p, ups)
            live = t < nb

            def pick(new, old):
                return jax.tree.map(lambda a, b: jnp.where(live, a, b), new, old)

            return (pick(p2, p), pick(st2, st), pick(ost2, ost), t + 1,
                    lsum + jnp.where(live, loss, 0.0)), None

        init = (params, state, opt_state, jnp.int32(0), jnp.float32(0.0))
        (p, st, _, _, lsum), _ = jax.lax.scan(one, init, batches,
                                              unroll=True if unroll else 1)
        return p, st, lsum / jnp.maximum(nb, 1).astype(jnp.float32)

    def make_agg(w):
        def agg(x):
            return jnp.einsum("k,k...->...", w,
                              x.astype(jnp.float32)).astype(x.dtype)
        return agg

    def wsum(acc, tree, wi):
        contrib = jax.tree.map(lambda b: wi * b.astype(jnp.float32), tree)
        return contrib if acc is None else jax.tree.map(jnp.add, acc, contrib)

    def cast_like(acc, ref):
        return jax.tree.map(lambda a, r: a.astype(r.dtype), acc, ref)

    def unrolled_clients(params, frozen, state, batches, nb_live):
        for i in range(nb_live.shape[0]):
            yield local_train(params, frozen, state,
                              jax.tree.map(lambda x: x[i], batches),
                              nb_live[i])

    def round_fn(params, frozen, state, batches, nb_live, weights):
        K = nb_live.shape[0]
        w = (weights / jnp.sum(weights)).astype(jnp.float32)
        if unroll:
            # incremental weighted sum: at most ONE extra model copy live at
            # a time (stacking K client trees would be an O(K) peak-memory
            # regression on the CPU path the memory model budgets for)
            agg_p = agg_st = None
            losses = []
            for i, (p_i, st_i, loss_i) in enumerate(
                    unrolled_clients(params, frozen, state, batches, nb_live)):
                agg_p = wsum(agg_p, p_i, w[i])
                agg_st = wsum(agg_st, st_i, w[i])
                losses.append(loss_i)
            return (cast_like(agg_p, params), cast_like(agg_st, state),
                    jnp.stack(losses))
        bcast = lambda x: jnp.broadcast_to(x[None], (K,) + x.shape)
        out_p, out_st, losses = jax.vmap(
            local_train, in_axes=(0, None, 0, 0, 0))(
            jax.tree.map(bcast, params), frozen, jax.tree.map(bcast, state),
            batches, nb_live)
        agg = make_agg(w)
        return jax.tree.map(agg, out_p), jax.tree.map(agg, out_st), losses

    # ----- defended variants (ISSUE 7) -----
    #
    # The defended round must satisfy two contracts at once: (a) with zero
    # faulty rows it is BIT-identical to the legacy round, and (b) a NaN
    # row never reaches the returned aggregate. Zero-weight masking alone
    # satisfies neither on its own: 0 x NaN = NaN poisons any fold, and —
    # measured — touching the unrolled CPU fold's graph in ANY way (a
    # keep-dependent weight chain, a trailing ``where`` select, even just
    # returning an extra output whose computation consumes the per-client
    # trees) perturbs XLA's fusion/FMA contraction decisions by 1 ulp.
    # The vmap/einsum form is robust to extra outputs (verified), the
    # unrolled fold is not. Hence the OBSERVE design:
    #   * vmap + sharded paths: ONE dispatch that runs the legacy weight
    #     chain + einsum/psum aggregate untouched and additionally returns
    #     the screen verdict ``keep`` and the stacked per-client outputs.
    #   * unrolled (CPU) path: the EXACT legacy jit computes the
    #     aggregate, and a separate screen-probe dispatch re-runs local
    #     training to produce (stacked outputs, keep). This doubles the
    #     local-training compute of defended unrolled rounds — the price
    #     of keeping the legacy fold's lowering byte-for-byte; defenses
    #     are opt-in and the CPU path is the small-model simulator.
    # The host wrapper accepts the legacy aggregate when every live row
    # passed, and recombines the kept rows via ``weighted_avg`` (the
    # sequential path's combine) when screening fired — faulty rounds
    # carry no bit-identity contract.

    def _verdict(norms, losses, weights):
        if screen:
            return _keep_mask(norms, losses, weights, screen_norm_mult)
        if aggregator != "mean":
            # robust aggregators always exclude non-finite rows (they
            # would poison the order statistics)
            return (jnp.isfinite(norms) & jnp.isfinite(losses)
                    & (weights > 0))
        # defenses off (fault injection only): corruption flows into the
        # mean unscreened — the benchmark's divergence arm
        return weights > 0

    def train_stacked(params, frozen, state, batches, nb_live, weights,
                      fault_codes=None):
        """Screen probe / stacked trainer: local training with the
        per-client results stacked, (optional) in-graph corruption, and
        the jitted screen verdict. No aggregation — the caller combines
        host-side."""
        K = nb_live.shape[0]
        if unroll:
            outs = list(unrolled_clients(params, frozen, state, batches,
                                         nb_live))
            losses = jnp.stack([o[2] for o in outs])
            out_p = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[o[0] for o in outs])
            out_st = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[o[1] for o in outs])
        else:
            bcast = lambda x: jnp.broadcast_to(x[None], (K,) + x.shape)
            out_p, out_st, losses = jax.vmap(
                local_train, in_axes=(0, None, 0, 0, 0))(
                jax.tree.map(bcast, params), frozen,
                jax.tree.map(bcast, state), batches, nb_live)
        if fault_codes is not None:
            out_p, losses = _apply_fault_codes(params, out_p, losses,
                                               fault_codes, fault_amplify)
        norms = _delta_norms(params, out_p)
        keep = _verdict(norms, losses, weights)
        return out_p, out_st, losses, keep

    def observe_vmap(params, frozen, state, batches, nb_live, weights,
                     fault_codes=None):
        """Single-dispatch defended round (vmap form): legacy einsum
        aggregate untouched + keep verdict + stacked outputs."""
        K = nb_live.shape[0]
        bcast = lambda x: jnp.broadcast_to(x[None], (K,) + x.shape)
        out_p, out_st, losses = jax.vmap(
            local_train, in_axes=(0, None, 0, 0, 0))(
            jax.tree.map(bcast, params), frozen, jax.tree.map(bcast, state),
            batches, nb_live)
        if fault_codes is not None:
            out_p, losses = _apply_fault_codes(params, out_p, losses,
                                               fault_codes, fault_amplify)
        norms = _delta_norms(params, out_p)
        keep = _verdict(norms, losses, weights)
        w = (weights / jnp.sum(weights)).astype(jnp.float32)
        agg = make_agg(w)
        return (jax.tree.map(agg, out_p), jax.tree.map(agg, out_st), losses,
                keep, out_p, out_st)

    def robust_fn(params, frozen, state, batches, nb_live, weights,
                  fault_codes=None):
        """Robust in-graph combine (``trimmed_mean``/``coord_median``) —
        no bit-identity contract, single dispatch, NaN-safe (masked rows
        sort to +inf and the order statistics index the valid prefix)."""
        out_p, out_st, losses, keep = train_stacked(
            params, frozen, state, batches, nb_live, weights, fault_codes)
        n_valid = jnp.sum(keep.astype(jnp.int32))
        safe = n_valid > 0
        rob = lambda x: _robust_leaf(x, keep, n_valid, aggregator, trim_beta)
        # all rows screened out -> the round is a no-op (never average NaN)
        agg_p = jax.tree.map(lambda x, p0: jnp.where(safe, rob(x), p0),
                             out_p, params)
        agg_st = jax.tree.map(lambda x, s0: jnp.where(safe, rob(x), s0),
                              out_st, state)
        return agg_p, agg_st, losses, keep

    def round_fn_compressed(params, frozen, state, batches, nb_live, weights,
                            residuals):
        K = nb_live.shape[0]
        w = (weights / jnp.sum(weights)).astype(jnp.float32)
        p_leaves, treedef = jax.tree.flatten(params)
        r_leaves = jax.tree.leaves(residuals)      # [K, leaf_size] each
        if unroll:
            # per-client incremental compress: only the [K, L] residual
            # state (inherent to error feedback) outlives a client's turn.
            # use_pallas instead collects every client's (idx, vals) rows
            # and folds the cohort in ONE sparse_agg kernel per leaf at the
            # end — the [K, k] row stacks are the same wire payload the
            # compressed uplink already carries, so no extra memory class.
            agg_acc = [jnp.zeros(p0.size, jnp.float32) for p0 in p_leaves]
            sent_rows = [[] for _ in p_leaves]      # use_pallas: (idx, vals)
            new_r_rows = [[] for _ in p_leaves]
            agg_st = None
            losses = []
            for i, (p_i, st_i, loss_i) in enumerate(
                    unrolled_clients(params, frozen, state, batches, nb_live)):
                for j, (p0, pi) in enumerate(zip(p_leaves,
                                                 jax.tree.leaves(p_i))):
                    delta = (pi.astype(jnp.float32).reshape(-1)
                             - p0.astype(jnp.float32).reshape(-1)
                             + r_leaves[j][i])
                    idx, vals = ingraph_topk(
                        delta, topk_keep(p0.size, compress_ratio))
                    if use_pallas:
                        sent_rows[j].append((idx, vals))
                    else:
                        agg_acc[j] = agg_acc[j].at[idx].add(w[i] * vals)
                    # residual = delta - sent: the kept entries were
                    # transmitted exactly, so they zero out
                    new_r_rows[j].append(delta.at[idx].set(0.0))
                agg_st = wsum(agg_st, st_i, w[i])
                losses.append(loss_i)
            if use_pallas:
                agg_acc = [
                    ingraph_sparse_aggregate(
                        jnp.stack([i_ for i_, _ in rows]),
                        jnp.stack([v_ for _, v_ in rows]), w, p0.size,
                        use_pallas=True)
                    for p0, rows in zip(p_leaves, sent_rows)]
            new_p = [(p0.astype(jnp.float32).reshape(-1) + acc)
                     .reshape(p0.shape).astype(p0.dtype)
                     for p0, acc in zip(p_leaves, agg_acc)]
            return (jax.tree.unflatten(treedef, new_p),
                    cast_like(agg_st, state), jnp.stack(losses),
                    jax.tree.unflatten(treedef, [jnp.stack(rows)
                                                 for rows in new_r_rows]))
        bcast = lambda x: jnp.broadcast_to(x[None], (K,) + x.shape)
        out_p, out_st, losses = jax.vmap(
            local_train, in_axes=(0, None, 0, 0, 0))(
            jax.tree.map(bcast, params), frozen, jax.tree.map(bcast, state),
            batches, nb_live)
        new_p, new_r = [], []
        for p0, pk, r in zip(p_leaves, jax.tree.leaves(out_p), r_leaves):
            agg_flat, r_new, _, _ = ingraph_compress_leaf(
                p0.astype(jnp.float32).reshape(-1),
                pk.astype(jnp.float32).reshape(K, -1), r, w, compress_ratio,
                use_pallas=use_pallas)
            new_p.append(agg_flat.reshape(p0.shape).astype(p0.dtype))
            new_r.append(r_new)
        # mutable state (BN stats) stays a dense server-side average — only
        # the parameter uplink is compressed
        return (jax.tree.unflatten(treedef, new_p),
                jax.tree.map(make_agg(w), out_st), losses,
                jax.tree.unflatten(treedef, new_r))

    # ----- sharded cohort path: shard_map over the client axis -----

    def psum_agg(w):
        def agg(x):
            part = jnp.einsum("k,k...->...", w, x.astype(jnp.float32))
            return jax.lax.psum(part, CLIENT_AXIS).astype(x.dtype)
        return agg

    def shard_train(params, frozen, state, batches, nb_live, weights):
        """Per-device body: train this shard's K/n_shards cohort rows
        against replicated params/frozen/state. Padded rows (nb_live=0,
        weight=0) train nothing and weigh nothing, so the global Eq. 1
        normalizer — one psum of the shard weight sums — sees only real
        clients."""
        K = nb_live.shape[0]
        wsum = jax.lax.psum(jnp.sum(weights), CLIENT_AXIS)
        w = (weights / wsum).astype(jnp.float32)
        bcast = lambda x: jnp.broadcast_to(x[None], (K,) + x.shape)
        out_p, out_st, losses = jax.vmap(
            local_train, in_axes=(0, None, 0, 0, 0))(
            jax.tree.map(bcast, params), frozen, jax.tree.map(bcast, state),
            batches, nb_live)
        return out_p, out_st, losses, w

    def round_fn_sharded(params, frozen, state, batches, nb_live, weights):
        out_p, out_st, losses, w = shard_train(params, frozen, state,
                                               batches, nb_live, weights)
        agg = psum_agg(w)
        return jax.tree.map(agg, out_p), jax.tree.map(agg, out_st), losses

    def round_fn_sharded_defended(params, frozen, state, batches, nb_live,
                                  weights, fault_codes=None):
        """Defended twin of ``round_fn_sharded`` (mean aggregator only),
        observe design like ``round_fn_defended``: the legacy per-shard
        weight normalization + psum-joined Eq. 1 aggregate run untouched,
        the screen's median statistic goes global with ONE ``all_gather``
        of the per-shard delta norms plus a ``psum`` of the valid count,
        and the per-shard ``keep`` verdicts + stacked client outputs come
        back partitioned along the client axis for the caller's host-side
        recombine when screening fires."""
        out_p, out_st, losses, w = shard_train(params, frozen, state,
                                               batches, nb_live, weights)
        if fault_codes is not None:
            out_p, losses = _apply_fault_codes(params, out_p, losses,
                                               fault_codes, fault_amplify)
        norms = _delta_norms(params, out_p)
        if screen:
            valid = (jnp.isfinite(norms) & jnp.isfinite(losses)
                     & (weights > 0))
            all_n = jax.lax.all_gather(jnp.where(valid, norms, jnp.inf),
                                       CLIENT_AXIS, tiled=True)
            n_v = jax.lax.psum(jnp.sum(valid.astype(jnp.int32)), CLIENT_AXIS)
            med = _lower_median(jnp.sort(all_n), n_v)
            outlier = jnp.isfinite(med) & (norms > screen_norm_mult * med
                                           + 1e-6)
            keep = valid & ~outlier
        else:
            keep = weights > 0
        agg = psum_agg(w)
        return (jax.tree.map(agg, out_p), jax.tree.map(agg, out_st), losses,
                keep, out_p, out_st)

    def round_fn_compressed_sharded(params, frozen, state, batches, nb_live,
                                    weights, residuals):
        out_p, out_st, losses, w = shard_train(params, frozen, state,
                                               batches, nb_live, weights)
        K = nb_live.shape[0]
        p_leaves, treedef = jax.tree.flatten(params)
        new_p, new_r = [], []
        for p0, pk, r in zip(p_leaves, jax.tree.leaves(out_p),
                             jax.tree.leaves(residuals)):
            p0_flat = p0.astype(jnp.float32).reshape(-1)
            agg_local, r_new, _, _ = ingraph_compress_leaf(
                p0_flat, pk.astype(jnp.float32).reshape(K, -1), r, w,
                compress_ratio)
            # agg_local = p0 + this shard's weighted sparse scatter-add;
            # the global Eq. 1 aggregate joins the partials with one psum
            agg = p0_flat + jax.lax.psum(agg_local - p0_flat, CLIENT_AXIS)
            new_p.append(agg.reshape(p0.shape).astype(p0.dtype))
            new_r.append(r_new)
        # BN state stays a dense weighted average (params-only uplink)
        return (jax.tree.unflatten(treedef, new_p),
                jax.tree.map(psum_agg(w), out_st), losses,
                jax.tree.unflatten(treedef, new_r))

    # the CPU backend cannot alias donated buffers — donate only where it
    # helps; the stacked batches (and carried residuals) are rebuilt from
    # host/per-client state every round, so both are safe to donate
    if n_shards > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        rep, csp = P(), P(CLIENT_AXIS)
        donate_ok = jax.default_backend() != "cpu"
        if compress_ratio is not None:
            fn = shard_map(round_fn_compressed_sharded, mesh=mesh,
                           in_specs=(rep, rep, rep, csp, csp, csp, csp),
                           out_specs=(rep, rep, csp, csp))
            return jax.jit(fn, donate_argnums=(3, 6) if donate_ok else ())
        if defended:
            # shard_map needs a fixed positional signature, so the codes
            # input only exists on injector-enabled builds
            if inject_faults:
                body = round_fn_sharded_defended
                in_sp = (rep, rep, rep, csp, csp, csp, csp)
            else:
                def body(p, f, s, b, nb, w):
                    return round_fn_sharded_defended(p, f, s, b, nb, w)
                in_sp = (rep, rep, rep, csp, csp, csp)
            out_sp = (rep, rep, csp, csp, csp, csp)
            smfn = jax.jit(shard_map(body, mesh=mesh, in_specs=in_sp,
                                     out_specs=out_sp),
                           donate_argnums=(3,) if donate_ok else ())

            def sharded_defended(params, frozen, state, batches, nb_live,
                                 weights, fault_codes=None):
                args = (params, frozen, state, batches, nb_live, weights)
                if fault_codes is not None:
                    args = args + (fault_codes,)
                agg_p, agg_st, losses, keep, out_p, out_st = smfn(*args)
                k = np.asarray(keep)
                if np.any(~k & (np.asarray(weights) > 0)):
                    agg_p, agg_st = _recombine_kept(params, state, out_p,
                                                    out_st, k, weights)
                return agg_p, agg_st, losses, keep

            return sharded_defended
        fn = shard_map(round_fn_sharded, mesh=mesh,
                       in_specs=(rep, rep, rep, csp, csp, csp),
                       out_specs=(rep, rep, csp))
        return jax.jit(fn, donate_argnums=(3,) if donate_ok else ())
    if compress_ratio is not None:
        donate = (3, 6) if jax.default_backend() != "cpu" else ()
        return jax.jit(round_fn_compressed, donate_argnums=donate)
    donate = (3,) if jax.default_backend() != "cpu" else ()
    if defended and aggregator != "mean":
        return jax.jit(robust_fn, donate_argnums=donate)
    if defended and not unroll:
        observe_jit = jax.jit(observe_vmap, donate_argnums=donate)

        def vmap_defended(params, frozen, state, batches, nb_live, weights,
                          fault_codes=None):
            agg_p, agg_st, losses, keep, out_p, out_st = observe_jit(
                params, frozen, state, batches, nb_live, weights,
                fault_codes)
            k = np.asarray(keep)
            if np.any(~k & (np.asarray(weights) > 0)):
                agg_p, agg_st = _recombine_kept(params, state, out_p,
                                                out_st, k, weights)
            return agg_p, agg_st, losses, keep

        return vmap_defended
    if defended:
        # unrolled two-dispatch form: the screen probe always runs, and the
        # aggregate comes from the EXACT legacy jit whenever every live row
        # passed clean. The batches buffer feeds BOTH jits, so it is never
        # donated here.
        legacy_jit = jax.jit(round_fn)
        probe_jit = jax.jit(train_stacked)

        def unrolled_defended(params, frozen, state, batches, nb_live,
                              weights, fault_codes=None):
            out_p, out_st, losses_p, keep = probe_jit(
                params, frozen, state, batches, nb_live, weights,
                fault_codes)
            k = np.asarray(keep)
            if fault_codes is None and not np.any(
                    ~k & (np.asarray(weights) > 0)):
                # every live row passed: take the untouched legacy graph's
                # aggregate — bitwise the undefended round
                agg_p, agg_st, losses = legacy_jit(params, frozen, state,
                                                   batches, nb_live, weights)
                return agg_p, agg_st, losses, keep
            # a corrupted or screened round voids the bit-identity
            # contract: combine the kept rows host-side (NaN-safe)
            agg_p, agg_st = _recombine_kept(params, state, out_p, out_st,
                                            k, weights)
            return agg_p, agg_st, losses_p, keep

        return unrolled_defended
    return jax.jit(round_fn, donate_argnums=donate)


# ---------------------------------------------------------------------------
# Round engine (tentpole #1 + #2 glue): cache + dispatch + grouping
# ---------------------------------------------------------------------------


@dataclass
class RoundEngine:
    """Executes federated rounds for a cohort of ``SimClient``s.

    ``loss_fn`` is the full-recompute stage loss; ``cached_loss_fn`` (when
    given) is its twin consuming pre-extracted prefix features under the
    same ``batch["x"]`` key; ``feature_fn(x) -> features`` is the frozen
    prefix itself. All three close over the current stage's frozen tree /
    plan — construct a fresh engine at every stage boundary, which is also
    what invalidates the feature cache on model growth (and, with
    compression on, resets error-feedback residuals, whose shapes follow
    the stage's active params).

    ``compress_ratio`` turns on in-graph top-k uplink sparsification with
    error feedback: residuals live on device in per-leaf [n_clients_seen,
    leaf_size] row pools (one gather on dispatch entry, one scatter on
    exit — NOT per-client stacking, which would reintroduce O(K x leaves)
    small device ops around the single fused dispatch), and come back
    updated — the round's hot path never materializes a dense per-client
    delta on host. ``last_uplink_bytes`` reports the (index, value)
    payload the round would have put on the wire.

    Feature caches are TIERED (fl/quant.py): ``use_cache`` values may be a
    tier name (``"f32"``/``"fp16"``/``"int8"``; legacy ``True`` means f32)
    and ``features_for`` quantizes on write, so a client's shard is held at
    the admitted precision from the moment it leaves the frozen prefix.
    int8 dequantization is fused into the cached-consumer loss inside the
    compiled round. ``compute_dtype`` (e.g. ``"bfloat16"``) runs local
    forward/backward in mixed precision with f32 master params/optimizer
    state and f32 Eq. 1 aggregation (``make_fused_round``).

    ``mesh`` (``launch.mesh.make_client_mesh``) switches the fused path to
    sharded cohort execution: the engine pads each per-tier group to a
    multiple of the client-axis size with inert rows (``nb_live=0``,
    ``weight=0``), partitions the stacked batches / live counts / weights /
    EF residuals along the axis, replicates params + frozen + BN state, and
    the shard_mapped dispatch joins per-device partial aggregates with one
    ``psum`` (see ``make_fused_round``). Mesh ``None`` (default) or a
    size-1 axis is the exact single-device path, bit-identical to pre-mesh
    trajectories. The sequential escape hatch ignores the mesh (it exists
    for the deadline/straggler path, which is latency- not
    throughput-bound).

    ISSUE 7 defenses: ``screen=True`` turns on the in-graph update screen
    (finite-check + ``screen_norm_mult`` x median delta-norm outlier mask,
    as zero-weight masking before Eq. 1; per-client verdicts land in
    ``last_screened``), ``aggregator`` selects
    ``"trimmed_mean"``/``"coord_median"`` robust combines, and
    ``run_round(..., faults={cid: kind})`` injects the corruption kinds of
    ``fl/faults.py`` — in-graph ``fault_codes`` on the fused dispatch,
    host-side ``apply_fault_to_update`` on the sequential path, same
    delta-space semantics. With screening on and no faults, rounds are
    bit-identical to an undefended engine (the legacy code paths are used
    verbatim whenever no defense is active). None of this composes with
    ``compress_ratio`` (error feedback would carry corrupted signal).
    """
    loss_fn: LossFn
    optimizer: Optimizer
    frozen: Any = None
    cached_loss_fn: Optional[LossFn] = None
    feature_fn: Optional[Callable] = None
    batch_size: int = 32
    local_epochs: int = 1
    clip_norm: float = 10.0
    fused: bool = True
    compress_ratio: Optional[float] = None
    compute_dtype: Optional[str] = None
    mesh: Any = None
    screen: bool = False
    screen_norm_mult: float = 8.0
    aggregator: str = "mean"
    trim_beta: float = 0.2
    fault_amplify: float = 50.0
    use_pallas: bool = False
    last_uplink_bytes: int = 0
    last_screened: Dict[int, bool] = field(default_factory=dict, repr=False)
    _features: Dict[int, EncodedFeatures] = field(default_factory=dict,
                                                  repr=False)
    _cache_version: int = field(default=0, repr=False)
    _cache_saved_version: int = field(default=-1, repr=False)
    _jit_cache: Dict[str, Callable] = field(default_factory=dict, repr=False)
    _res_pool: List = field(default_factory=list, repr=False)   # per leaf [cap, L]
    _res_row: Dict[int, int] = field(default_factory=dict, repr=False)

    # ----- frozen-prefix feature cache (tiered) -----

    def features_for(self, client: SimClient,
                     tier: str = "f32") -> EncodedFeatures:
        """Client's shard pushed through the frozen prefix once (eval mode)
        and encoded at ``tier`` on write; memoized until the engine (== the
        stage) is replaced. A tier change re-extracts and re-encodes (does
        not happen mid-stage: admission is decided per stage)."""
        enc = self._features.get(client.client_id)
        if enc is None or enc.tier != tier:
            fn = self._jit_cache.setdefault("feature", jax.jit(self.feature_fn))
            enc = encode_features(
                np.asarray(fn(jnp.asarray(client.data["x"]))), tier)
            self._features[client.client_id] = enc
            self._cache_version += 1
        return enc

    def cache_nbytes(self) -> int:
        """Resident cache footprint at the ACTUAL stored dtypes (int8
        values + their f32 scale vectors count as stored, not as the f32
        equivalent)."""
        return sum(f.nbytes for f in self._features.values())

    def cache_tiers(self) -> Dict[int, str]:
        """Tier actually stored per cached client."""
        return {cid: enc.tier for cid, enc in self._features.items()}

    def cache_state(self) -> Optional[Dict[str, np.ndarray]]:
        """Per-client tier assignments + encoded features (incl. int8 quant
        scales) as checkpointable arrays — a resumed run consumes the exact
        bytes the crashed run trained on, so bit-identical resume holds
        across a tier decision. None when nothing is cached yet."""
        if not self._features:
            return None
        cids = sorted(self._features)
        out = {"ids": np.asarray(cids, np.int64),
               "tiers": np.asarray([CACHE_TIERS.index(self._features[c].tier)
                                    for c in cids], np.int64)}
        for i, cid in enumerate(cids):
            enc = self._features[cid]
            out[f"val{i}"] = np.asarray(enc.values)
            if enc.scale is not None:
                out[f"scale{i}"] = np.asarray(enc.scale)
        return out

    def cache_state_if_changed(self) -> Optional[Dict[str, np.ndarray]]:
        """``cache_state`` only when the cache changed since the last call.
        Within a stage the cache is immutable once every participant is
        encoded, so checkpoints stop re-writing identical feature bytes
        every round; a checkpoint without a ``cache`` subtree resumes by
        recomputing the features from the restored frozen tree, which is
        deterministic (bit-identical on the same backend)."""
        if not self._features or self._cache_version == self._cache_saved_version:
            return None
        self._cache_saved_version = self._cache_version
        return self.cache_state()

    def load_cache_state(self, tree: Dict[str, np.ndarray]) -> None:
        """Restore ``cache_state`` output."""
        self._features = {}
        tiers = np.asarray(tree["tiers"])
        for i, cid in enumerate(np.asarray(tree["ids"])):
            self._features[int(cid)] = EncodedFeatures(
                CACHE_TIERS[int(tiers[i])], np.asarray(tree[f"val{i}"]),
                (np.asarray(tree[f"scale{i}"]) if f"scale{i}" in tree
                 else None))
        self._cache_version += 1

    # ----- error-feedback residual state (on-device, per client) -----

    def _residual_rows(self, cids: List[int], leaves) -> np.ndarray:
        """Pool row index per client, growing the per-leaf [cap, L] pools
        (zero-filled == empty residual) as new clients appear."""
        for cid in cids:
            if cid not in self._res_row:
                self._res_row[cid] = len(self._res_row)
        need = len(self._res_row)
        if not self._res_pool:
            self._res_pool = [jnp.zeros((need, l.size), jnp.float32)
                              for l in leaves]
        elif self._res_pool[0].shape[0] < need:
            cap = max(need, 2 * self._res_pool[0].shape[0])
            self._res_pool = [
                jnp.concatenate([p, jnp.zeros((cap - p.shape[0], p.shape[1]),
                                              jnp.float32)]) for p in self._res_pool]
        return np.asarray([self._res_row[cid] for cid in cids])

    def _gather_residuals(self, cids: List[int], params):
        """Cohort residuals as a params-shaped tree of [K, L] leaves — ONE
        gather per leaf from the resident pool."""
        leaves, treedef = jax.tree.flatten(params)
        rows = self._residual_rows(cids, leaves)
        rows_dev = jnp.asarray(rows)
        return jax.tree.unflatten(treedef,
                                  [p[rows_dev] for p in self._res_pool]), rows

    def _scatter_residuals(self, rows: np.ndarray, new_residuals):
        rows_dev = jnp.asarray(rows)
        self._res_pool = [pool.at[rows_dev].set(leaf) for pool, leaf in
                          zip(self._res_pool, jax.tree.leaves(new_residuals))]

    def client_residuals(self, cid: int) -> List[jnp.ndarray]:
        """This client's per-leaf error-feedback residual vectors."""
        row = self._res_row[cid]
        return [p[row] for p in self._res_pool]

    def ef_state(self) -> Optional[Dict[str, np.ndarray]]:
        """Error-feedback residual pools + client->row map as checkpointable
        arrays (None when compression is off / nothing carried yet)."""
        if not self._res_pool:
            return None
        cids = sorted(self._res_row)
        return {"rows_ids": np.asarray(cids, np.int64),
                "rows_idx": np.asarray([self._res_row[c] for c in cids],
                                       np.int64),
                **{f"pool{i}": np.asarray(p)
                   for i, p in enumerate(self._res_pool)}}

    def load_ef_state(self, tree: Dict[str, np.ndarray]) -> None:
        """Restore ``ef_state`` output — resumed compressed runs carry the
        exact per-client un-transmitted residual signal forward."""
        self._res_row = {int(c): int(i) for c, i in
                         zip(np.asarray(tree["rows_ids"]),
                             np.asarray(tree["rows_idx"]))}
        pools = []
        i = 0
        while f"pool{i}" in tree:
            pools.append(jnp.asarray(tree[f"pool{i}"], jnp.float32))
            i += 1
        self._res_pool = pools

    def per_client_uplink_bytes(self, params) -> int:
        """One client's (index, value) payload for the current stage — what
        the time model charges against each client's uplink rate."""
        return self._uplink_bytes(params, 1)

    def residual_norms(self) -> Dict[int, float]:
        """Per-client ||error-feedback residual||_2 — feeds
        ``ClientPopulation.ef_residual_norm`` for selection policies that
        prefer clients with pent-up un-transmitted signal."""
        if not self._res_pool:
            return {}
        fn = self._jit_cache.setdefault(
            "res_norm", jax.jit(lambda pools: jnp.sqrt(
                sum(jnp.sum(p.astype(jnp.float32) ** 2, axis=1)
                    for p in pools))))
        norms = np.asarray(fn(self._res_pool))
        return {cid: float(norms[row]) for cid, row in self._res_row.items()}

    def _uplink_bytes(self, params, n_clients: int) -> int:
        """(index, value) payload per client, summed over the cohort."""
        leaves = jax.tree.leaves(params)
        if self.compress_ratio is None:
            return n_clients * sum(l.size * 4 for l in leaves)
        return n_clients * sum(topk_keep(l.size, self.compress_ratio) * 8
                               for l in leaves)

    # ----- round execution -----

    def run_round(self, clients: Dict[int, SimClient], selected: List[int],
                  params, state, round_idx: int, *,
                  use_cache: Optional[Dict[int, bool]] = None,
                  sequential: Optional[bool] = None,
                  faults: Optional[Dict[int, str]] = None
                  ) -> Tuple[Any, Any, Dict[int, float]]:
        """One federated round over ``selected``. Returns (params, state,
        per-client mean loss). Splits the cohort into per-cache-tier groups
        plus a recompute group (their batch shapes/dtypes differ), runs each
        as one fused dispatch, and combines the group aggregates by total
        weight — algebraically the same Eq. 1 average as a single flat
        cohort. ``use_cache`` values are tier names (legacy booleans still
        accepted: ``True`` == the exact f32 tier). ``faults`` maps client
        ids in the cohort to ``fl/faults.CORRUPT_KINDS`` — their trained
        updates are corrupted (delta-space) before screening/aggregation;
        crash/hang kinds never reach the engine (the aggregation policies
        drop those clients upstream)."""
        use_cache = use_cache or {}
        seq = (not self.fused) if sequential is None else sequential
        if self.aggregator not in AGGREGATORS:
            raise ValueError(f"unknown aggregator {self.aggregator!r}; "
                             f"choose from {AGGREGATORS}")
        faults = {int(c): k for c, k in (faults or {}).items()
                  if k in CORRUPT_KINDS} or None
        if ((self.screen or self.aggregator != "mean" or faults)
                and self.compress_ratio is not None):
            raise ValueError("screening / robust aggregation / fault "
                             "injection do not compose with compress_ratio")
        self.last_uplink_bytes = 0
        self.last_screened = {}
        groups: Dict[Optional[str], List[int]] = {}
        for cid in selected:
            tier = (normalize_tier(use_cache.get(cid))
                    if self.cached_loss_fn is not None else None)
            groups.setdefault(tier, []).append(cid)

        partials = []  # (agg_params, agg_state, group_weight)
        losses: Dict[int, float] = {}
        for tier, cids in groups.items():
            runner = self._run_sequential if seq else self._run_fused
            p_g, s_g, l_g, w_g = runner(clients, cids, params, state,
                                        round_idx, tier=tier, faults=faults)
            partials.append((p_g, s_g, w_g))
            losses.update(l_g)
        if len(partials) == 1:
            return partials[0][0], partials[0][1], losses
        w = np.asarray([p[2] for p in partials], np.float64)
        w /= w.sum()
        return (weighted_avg([p[0] for p in partials], w),
                weighted_avg([p[1] for p in partials], w), losses)

    # ----- fused path -----

    def _client_arrays(self, client: SimClient,
                       tier: Optional[str]) -> Dict[str, np.ndarray]:
        if tier is not None:
            data = dict(client.data)
            data.update(feature_batch_arrays(self.features_for(client, tier)))
            return data
        return client.data

    def _group_loss_fn(self, tier: Optional[str]) -> LossFn:
        """The group's loss: cached groups consume encoded features with
        dequantization fused in-graph (fl/quant.make_tiered_loss)."""
        if tier is None:
            return self.loss_fn
        return make_tiered_loss(self.cached_loss_fn, tier, self.compute_dtype,
                                use_pallas=self.use_pallas)

    def _run_fused(self, clients, cids, params, state, round_idx, *, tier,
                   faults=None):
        codes = corrupt_codes(faults, cids)
        defended = (self.screen or self.aggregator != "mean"
                    or codes is not None)
        bs, ep = self.batch_size, self.local_epochs
        plans = {cid: batch_index_plan(clients[cid].num_samples, bs, ep,
                                       clients[cid].round_seed(round_idx))
                 for cid in cids}
        nb_live = np.asarray([len(plans[cid]) for cid in cids], np.int32)
        nb = max(int(nb_live.max()), 1)
        stacked: Dict[str, np.ndarray] = {}
        sample = self._client_arrays(clients[cids[0]], tier)
        for key in sample:
            rows = []
            for cid in cids:
                data = self._client_arrays(clients[cid], tier)[key]
                plan = plans[cid]
                # pad exhausted clients by cycling their plan (masked anyway)
                idx = np.stack([plan[t % len(plan)] if plan
                                else np.zeros(bs, np.int64)
                                for t in range(nb)])
                rows.append(data[idx])
            stacked[key] = np.stack(rows)
        weights = np.asarray([clients[cid].num_samples for cid in cids],
                             np.float32)
        n_shards = client_axis_size(self.mesh)
        pad = (-len(cids)) % n_shards if n_shards > 1 else 0
        if pad:
            # pad the cohort to a multiple of the client-axis size with
            # inert rows: nb_live=0 masks every local step and weight=0
            # zeroes the Eq. 1 contribution, so padded row CONTENT is never
            # consumed (first row repeated only to keep shapes/dtypes)
            stacked = {k: np.concatenate([v, np.repeat(v[:1], pad, axis=0)])
                       for k, v in stacked.items()}
            nb_live = np.concatenate([nb_live, np.zeros(pad, np.int32)])
        w_in = (np.concatenate([weights, np.zeros(pad, np.float32)])
                if pad else weights)
        key = "fused" if tier is None else f"fused_cached_{tier}"
        if self.use_pallas:
            key += "|pallas"
        if defended:
            # an undefended engine round keeps the LEGACY compiled fn (and
            # its bit-exact trajectory); the defended build is keyed by its
            # defense config so faulted and clean rounds don't retrace each
            # other's variant
            key += (f"|scr{int(self.screen)}|agg:{self.aggregator}"
                    f"|flt{int(codes is not None)}")
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = make_fused_round(self._group_loss_fn(tier),
                                  self.optimizer, clip_norm=self.clip_norm,
                                  compress_ratio=self.compress_ratio,
                                  compute_dtype=self.compute_dtype,
                                  mesh=self.mesh,
                                  screen=self.screen if defended else False,
                                  screen_norm_mult=self.screen_norm_mult,
                                  aggregator=(self.aggregator if defended
                                              else "mean"),
                                  trim_beta=self.trim_beta,
                                  inject_faults=codes is not None,
                                  fault_amplify=self.fault_amplify,
                                  use_pallas=self.use_pallas)
            self._jit_cache[key] = fn
        cached = tier is not None
        frozen = {} if cached else (self.frozen if self.frozen is not None else {})
        batches = {k: jnp.asarray(v) for k, v in stacked.items()}
        nb_dev, w_dev = jnp.asarray(nb_live), jnp.asarray(w_in)
        codes_dev = None
        if codes is not None:
            codes_dev = jnp.asarray(np.concatenate(
                [codes, np.zeros(pad, np.int32)]) if pad else codes)
        if n_shards > 1:
            # explicit placement: cohort-stacked rows partition along the
            # client axis, model trees replicate — no implicit resharding
            # inside the dispatch
            params, frozen, state = replicate(self.mesh,
                                              (params, frozen, state))
            batches, nb_dev, w_dev = shard_cohort(self.mesh,
                                                  (batches, nb_dev, w_dev))
            if codes_dev is not None:
                codes_dev = shard_cohort(self.mesh, codes_dev)
        args = (params, frozen, state, batches, nb_dev, w_dev)
        if self.compress_ratio is not None:
            residuals, rows = self._gather_residuals(cids, params)
            if pad:
                residuals = jax.tree.map(
                    lambda r: jnp.concatenate(
                        [r, jnp.zeros((pad, r.shape[1]), r.dtype)]),
                    residuals)
            if n_shards > 1:
                residuals = shard_cohort(self.mesh, residuals)
            p_g, s_g, l_g, new_r = fn(*args, residuals)
            if pad:
                new_r = jax.tree.map(lambda r: r[:len(cids)], new_r)
            if n_shards > 1:
                # bring the sharded residual rows back to the resident
                # single-device pools (one host round-trip per round; the
                # pools themselves are not sharded — they index by client
                # id, not cohort slot)
                new_r = jax.tree.map(lambda r: jnp.asarray(np.asarray(r)),
                                     new_r)
            self._scatter_residuals(rows, new_r)
        else:
            out = fn(*args, codes_dev) if codes_dev is not None else fn(*args)
            if defended:
                # every defended build returns a uniform 4-tuple; the mean
                # builds are host wrappers that already recombined the kept
                # rows whenever screening fired
                p_g, s_g, l_g, keep = out
                if self.screen:
                    k_host = np.asarray(keep)[:len(cids)]
                    # True == this client's update was screened OUT
                    self.last_screened.update(
                        {cid: not bool(k_host[i])
                         for i, cid in enumerate(cids)})
            else:
                p_g, s_g, l_g = out
        self.last_uplink_bytes += self._uplink_bytes(params, len(cids))
        # ONE blocking sync for the whole cohort (padded rows sliced off)
        l_host = np.asarray(l_g)[:len(cids)]
        return (p_g, s_g, {cid: float(l_host[i]) for i, cid in enumerate(cids)},
                float(weights.sum()))

    # ----- sequential escape hatch (deadline/straggler path) -----

    def _seq_step(self, tier: Optional[str]):
        key = "seq" if tier is None else f"seq_cached_{tier}"
        fn = self._jit_cache.get(key)
        if fn is None:
            loss_fn = make_input_cast_loss(self._group_loss_fn(tier),
                                           self.compute_dtype)
            cdt = (jnp.dtype(self.compute_dtype)
                   if self.compute_dtype is not None else None)

            def step(p, frozen, st, ost, batch):
                if cdt is None:
                    (loss, st2), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(p, frozen, st, batch)
                else:
                    # mixed precision mirrors make_fused_round: bf16
                    # forward/backward, f32 master params + optimizer state
                    (loss, st2), grads = jax.value_and_grad(
                        lambda pc: loss_fn(pc, cast_floating(frozen, cdt),
                                           st, batch),
                        has_aux=True)(cast_floating(p, cdt))
                    grads = jax.tree.map(lambda g, m: g.astype(m.dtype),
                                         grads, p)
                    st2 = jax.tree.map(lambda a, m: a.astype(m.dtype), st2, st)
                    loss = loss.astype(jnp.float32)
                grads, _ = clip_by_global_norm(grads, self.clip_norm)
                ups, ost2 = self.optimizer.update(grads, ost, p)
                return apply_updates(p, ups), st2, ost2, loss

            fn = self._jit_cache[key] = jax.jit(step)
        return fn

    def _seq_compress(self):
        """Per-client jitted compress step for the sequential path — same
        ``ingraph_compress_leaf`` math as the fused dispatch (K=1), so
        sequential and fused compressed rounds agree."""
        fn = self._jit_cache.get("seq_compress")
        if fn is None:
            ratio = self.compress_ratio
            use_pallas = self.use_pallas

            def comp(params, p_i, res_leaves):
                leaves, treedef = jax.tree.flatten(params)
                new_p, new_r = [], []
                for p0, pi, r in zip(leaves, jax.tree.leaves(p_i), res_leaves):
                    sent, r_new, _, _ = ingraph_compress_leaf(
                        p0.astype(jnp.float32).reshape(-1),
                        pi.astype(jnp.float32).reshape(1, -1), r[None, :],
                        jnp.ones(1, jnp.float32), ratio,
                        use_pallas=use_pallas)
                    new_p.append(sent.reshape(p0.shape).astype(p0.dtype))
                    new_r.append(r_new[0])
                return jax.tree.unflatten(treedef, new_p), new_r

            fn = self._jit_cache["seq_compress"] = jax.jit(comp)
        return fn

    def _robust_combine(self):
        """Jitted robust aggregate over ALREADY-KEPT sequential updates —
        the same ``_robust_leaf`` order statistics the fused dispatch uses
        (host screening removed the masked rows, so keep is all-true)."""
        fn = self._jit_cache.get("robust_combine")
        if fn is None:
            agg_name, beta = self.aggregator, self.trim_beta

            def comb(p_trees, s_trees):
                n = len(p_trees)
                keep = jnp.ones(n, bool)
                nv = jnp.int32(n)
                rob = lambda x: _robust_leaf(x, keep, nv, agg_name, beta)
                sp = jax.tree.map(lambda *xs: jnp.stack(xs), *p_trees)
                ss = jax.tree.map(lambda *xs: jnp.stack(xs), *s_trees)
                return jax.tree.map(rob, sp), jax.tree.map(rob, ss)

            fn = self._jit_cache["robust_combine"] = jax.jit(comb)
        return fn

    def _host_keep(self, norms, l_arr, w_arr):
        """Numpy mirror of the in-graph ``_keep_mask`` (same lower-median /
        mult semantics), so sequential and fused rounds screen the same
        clients."""
        finite = np.isfinite(norms) & np.isfinite(l_arr)
        valid = finite & (w_arr > 0)
        if not self.screen:
            # robust aggregators always exclude non-finite rows (they
            # would poison the order statistics); the plain mean without
            # screening lets corruption through — the divergence arm
            return valid if self.aggregator != "mean" else (w_arr > 0)
        n_v = int(valid.sum())
        med = np.sort(np.where(valid, norms, np.inf))[max(n_v - 1, 0) // 2]
        outlier = bool(np.isfinite(med)) & (
            norms > self.screen_norm_mult * med + 1e-6)
        return valid & ~outlier

    def _run_sequential(self, clients, cids, params, state, round_idx, *,
                        tier, faults=None):
        step = self._seq_step(tier)
        frozen = ({} if tier is not None
                  else (self.frozen if self.frozen is not None else {}))
        faults = faults or {}
        defended = (self.screen or self.aggregator != "mean"
                    or any(cid in faults for cid in cids))
        updates, weights, losses = [], [], {}
        for cid in cids:
            c = clients[cid]
            data = self._client_arrays(c, tier)
            p_i, s_i = params, state
            ost = self.optimizer.init(params)
            batch_losses = []
            for idx in batch_index_plan(c.num_samples, self.batch_size,
                                        self.local_epochs,
                                        c.round_seed(round_idx)):
                jb = {k: jnp.asarray(v[idx]) for k, v in data.items()}
                p_i, s_i, ost, loss = step(p_i, frozen, s_i, ost, jb)
                batch_losses.append(float(loss))
            if self.compress_ratio is not None:
                rows = self._residual_rows([cid], jax.tree.leaves(params))
                p_i, new_r = self._seq_compress()(
                    params, p_i, [p[rows[0]] for p in self._res_pool])
                self._res_pool = [p.at[rows[0]].set(r) for p, r in
                                  zip(self._res_pool, new_r)]
            loss_i = float(np.mean(batch_losses)) if batch_losses else 0.0
            kind = faults.get(cid)
            if kind is not None:
                # host-side twin of the in-graph fault_codes transform
                p_i = apply_fault_to_update(kind, params, p_i,
                                            amplify=self.fault_amplify)
                if kind in ("nan", "inf"):
                    loss_i = float("nan")
            updates.append((p_i, s_i))
            weights.append(c.num_samples)
            losses[cid] = loss_i
        self.last_uplink_bytes += self._uplink_bytes(params, len(cids))
        w_arr = np.asarray(weights, np.float64)
        if defended:
            norm_fn = self._jit_cache.setdefault(
                "delta_norm", jax.jit(_delta_norm_one))
            norms = np.asarray([float(norm_fn(params, u[0]))
                                for u in updates])
            l_arr = np.asarray([losses[cid] for cid in cids])
            keep = self._host_keep(norms, l_arr, w_arr)
            if self.screen:
                self.last_screened.update(
                    {cid: not bool(keep[i]) for i, cid in enumerate(cids)})
            if not keep.any():
                # every update screened out: the group is a no-op (the
                # fused path's in-graph `safe` fallback), weight unchanged
                return params, state, losses, float(w_arr.sum())
            if self.aggregator != "mean":
                kept = [u for u, k in zip(updates, keep) if k]
                p_g, s_g = self._robust_combine()([u[0] for u in kept],
                                                  [u[1] for u in kept])
                return p_g, s_g, losses, float(w_arr.sum())
            if not keep.all():
                kept_w = w_arr[keep]
                kept = [u for u, k in zip(updates, keep) if k]
                w = kept_w / kept_w.sum()
                return (weighted_avg([u[0] for u in kept], w),
                        weighted_avg([u[1] for u in kept], w), losses,
                        float(w_arr.sum()))
            # all kept + mean -> fall through to the EXACT legacy combine
            # (zero-fault bit-identity on the sequential path too)
        w = w_arr / w_arr.sum()
        return (weighted_avg([u[0] for u in updates], w),
                weighted_avg([u[1] for u in updates], w), losses,
                float(np.sum(weights)))


# ---------------------------------------------------------------------------
# LM backend: cached-prefix federated round (reuses core/freezing.py's
# pod-fused make_fed_round_step shape; consumes features instead)
# ---------------------------------------------------------------------------


def make_lm_cached_fed_round_step(model, plan, local_opt: Optimizer, *,
                                  num_pods: int, local_steps: int,
                                  remat: bool = True, clip_norm: float = 1.0,
                                  constrain_podded=None, remat_policy=None,
                                  donate: bool = True,
                                  feature_tier: str = "f32",
                                  compute_dtype: Optional[str] = None):
    """Cached sibling of ``freezing.make_fed_round_step``: the batch carries
    ``h0``/``aux0`` (frozen-prefix outputs, computed once per stage via
    ``freezing.stage_prefix_features``) with leading dims
    [num_pods, local_steps, ...]; only the active suffix is executed and
    differentiated. Jitted with ``donate_argnums`` on the active params (the
    per-pod optimizer state is born and dies inside the jit).

    ``feature_tier`` selects the cache storage precision (fl/quant.py):
    with ``"fp16"`` the batch's ``h0`` arrives f16, with ``"int8"`` it
    arrives int8 alongside ``h0_scale`` (``quantize_int8`` of the prefix
    output) and is dequantized INSIDE the compiled step — the f32/bf16
    feature tensor never exists outside the dispatch. ``compute_dtype``
    overrides the dtype the decoded features (and the active params) are
    evaluated in; default keeps the model's native compute dtype.

    Requires a static prefix — caching under a training embedding (stage 0)
    or a weight-tied shared-attention prefix (zamba2) would silently train
    on stale features, so that is rejected here."""
    from repro.core.freezing import cached_stage_loss_fn, prefix_is_static

    if not prefix_is_static(plan):
        raise ValueError(
            f"stage {plan.stage}: frozen prefix is not a fixed feature "
            "extractor (training embedding or tied shared-attention in the "
            "prefix) — use freezing.make_fed_round_step instead")

    feature_tier = normalize_tier(feature_tier) or "f32"
    base_loss = cached_stage_loss_fn(model, plan, remat=remat,
                                     remat_policy=remat_policy)
    h_dt = jnp.dtype(compute_dtype or model.cfg.compute_dtype)
    cdt = jnp.dtype(compute_dtype) if compute_dtype is not None else None

    def loss_fn(act, batch):
        if feature_tier == "f32":
            return base_loss(act, batch)
        b = dict(batch)
        if feature_tier == "int8":
            b["h0"] = (b["h0"].astype(jnp.float32)
                       * b.pop("h0_scale").astype(jnp.float32)).astype(h_dt)
        else:  # fp16
            b["h0"] = b["h0"].astype(h_dt)
        return base_loss(act, b)

    def local_train(active, batches):
        opt_state = local_opt.init(active)

        def one(carry, batch):
            act, ost = carry
            if cdt is None:
                loss, grads = jax.value_and_grad(loss_fn)(act, batch)
            else:
                loss, grads = jax.value_and_grad(loss_fn)(
                    cast_floating(act, cdt), batch)
                grads = jax.tree.map(lambda g, m: g.astype(m.dtype),
                                     grads, act)
                loss = loss.astype(jnp.float32)
            grads, _ = clip_by_global_norm(grads, clip_norm)
            ups, ost = local_opt.update(grads, ost, act)
            return (apply_updates(act, ups), ost), loss

        (active, _), losses = jax.lax.scan(one, (active, opt_state), batches)
        return active, jnp.mean(losses)

    def round_step(active, batch, weights):
        podded = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (num_pods,) + x.shape), active)
        if constrain_podded is not None:
            podded = constrain_podded(podded)
        podded, losses = jax.vmap(local_train, in_axes=(0, 0))(podded, batch)
        w = (weights / jnp.sum(weights)).astype(jnp.float32)

        def agg(x):
            return jnp.einsum("p,p...->...", w,
                              x.astype(jnp.float32)).astype(x.dtype)

        return jax.tree.map(agg, podded), {"loss": jnp.sum(w * losses)}

    donate = donate and jax.default_backend() != "cpu"
    return jax.jit(round_step, donate_argnums=(0,) if donate else ())
