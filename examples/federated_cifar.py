"""The paper's testbed end-to-end: SmartFreeze vs vanilla FL on a synthetic
CIFAR-like task with 20 heterogeneous clients (Dirichlet non-IID, memory +
compute heterogeneity). Prints round-by-round accuracy, the stage-freeze
points, the Eq.(4) per-stage memory model — and the virtual clock: pass
``--policy deadline`` (or ``async``) to run the same experiment under
deadline-based partial aggregation or FedBuff-style buffered async, and
``--ckpt-dir`` / ``--resume`` to checkpoint every round and continue a
killed run bit-identically (loss, perturbation and selection series all
pick up where they left off; under ``async`` the in-flight dispatches are
not checkpointed, so a resumed run re-dispatches them — sync/deadline are
the bit-identical policies).

Run:  PYTHONPATH=src python examples/federated_cifar.py [--rounds-per-stage 8]
      PYTHONPATH=src python examples/federated_cifar.py \
          --policy deadline --ckpt-dir /tmp/fed_ck        # kill it mid-run
      PYTHONPATH=src python examples/federated_cifar.py \
          --policy deadline --ckpt-dir /tmp/fed_ck --resume
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticVision
from repro.fl.client import make_client_fleet
from repro.fl.server import SmartFreezeServer, cnn_stage_memory_bytes
from repro.fl.sim import (AsyncBufferedAggregation, AvailabilityTrace,
                          DeadlineAggregation, FleetTimeModel)
from repro.models.cnn import CNN, CNNConfig

ap = argparse.ArgumentParser()
ap.add_argument("--rounds-per-stage", type=int, default=8)
ap.add_argument("--clients", type=int, default=20)
ap.add_argument("--policy", choices=["sync", "deadline", "async"],
                default="sync")
ap.add_argument("--dropout", type=float, default=0.0,
                help="per-(client, round) mid-round dropout probability")
ap.add_argument("--link-mbps", type=float, default=0.0,
                help=">0: uplink rate in MB/s (payload time enters the clock)")
ap.add_argument("--cache-tiers", choices=["f32", "all"], default="f32",
                help="feature-cache admission ladder: f32-only (exact seed "
                     "behavior) or the full f32->fp16->int8 ladder")
ap.add_argument("--compute-dtype", default=None,
                help="e.g. bfloat16: mixed-precision local training with "
                     "f32 master params")
ap.add_argument("--ckpt-dir", default=None)
ap.add_argument("--ckpt-every", type=int, default=1)
ap.add_argument("--resume", action="store_true")
args = ap.parse_args()

sv = SyntheticVision(num_classes=10, image_size=16)
train_data = sv.sample(3000, seed=1)
test = sv.sample(500, seed=2)
parts = dirichlet_partition(train_data["y"], args.clients, alpha=1.0, seed=0)
clients = make_client_fleet(train_data, parts, scenario="low")

cfg = CNNConfig("resnet_mini", "resnet", stage_sizes=(1, 1, 1),
                stage_channels=(16, 32, 64))
model = CNN(cfg)
params, state = model.init(jax.random.PRNGKey(0))

print("Eq.(4) stage memory model (batch 32):")
for s in range(3):
    mb = cnn_stage_memory_bytes(model, s, 32) / 2**20
    print(f"  stage {s}: {mb:7.1f} MiB")

def eval_fn(p, s, stage):
    logits, _ = model.apply(p, s, jnp.asarray(test["x"]), train=False)
    return float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())

policy = {"sync": "sync",
          "deadline": DeadlineAggregation(factor=1.5),
          "async": AsyncBufferedAggregation(buffer_size=4)}[args.policy]
time_model = None
if args.link_mbps > 0:
    time_model = FleetTimeModel.from_clients(
        clients, link_rates=[args.link_mbps * 1e6] * len(clients))
availability = (AvailabilityTrace(p_dropout=args.dropout)
                if args.dropout > 0 else None)
mgr = CheckpointManager(args.ckpt_dir, async_save=False) if args.ckpt_dir else None

srv = SmartFreezeServer(model, clients, clients_per_round=6, local_epochs=1,
                        batch_size=32, rounds_per_stage=args.rounds_per_stage,
                        aggregation=policy, time_model=time_model,
                        availability=availability,
                        cache_tiers=("f32",) if args.cache_tiers == "f32"
                        else "all",
                        cache_time_scale=args.cache_tiers != "f32",
                        compute_dtype=args.compute_dtype,
                        pace_kwargs=dict(min_rounds=4, mu=2, slope_lambda=2e-2))
out = srv.run(params, state, eval_fn=eval_fn, eval_every=2,
              ckpt_manager=mgr, ckpt_every=args.ckpt_every if mgr else 0,
              resume=args.resume)
print(f"\n{out['rounds']} rounds, {out['virtual_time']:.2e} virtual seconds "
      f"({args.policy}):")
for rr in out["history"]:
    acc = f" acc={rr.test_acc:.3f}" if rr.test_acc is not None else ""
    frz = "  << FROZEN" if rr.frozen else ""
    drop = f" -{len(rr.dropped)}" if rr.dropped else ""
    print(f"  r{rr.round_idx:3d} stage{rr.stage} t={rr.virtual_time:8.2e}s "
          f"loss={rr.loss:.3f}{drop}{acc}{frz}")
