"""The paper's testbed end-to-end: SmartFreeze vs vanilla FL on a synthetic
CIFAR-like task with 20 heterogeneous clients (Dirichlet non-IID, memory +
compute heterogeneity). Prints round-by-round accuracy and the stage-freeze
points, plus the Eq.(4) per-stage memory model.

Run:  PYTHONPATH=src python examples/federated_cifar.py [--rounds-per-stage 8]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import jax
import jax.numpy as jnp

from repro.data.partition import dirichlet_partition
from repro.data.synthetic import SyntheticVision
from repro.fl.client import make_client_fleet
from repro.fl.server import SmartFreezeServer, cnn_stage_memory_bytes
from repro.models.cnn import CNN, CNNConfig

ap = argparse.ArgumentParser()
ap.add_argument("--rounds-per-stage", type=int, default=8)
ap.add_argument("--clients", type=int, default=20)
args = ap.parse_args()

sv = SyntheticVision(num_classes=10, image_size=16)
train_data = sv.sample(3000, seed=1)
test = sv.sample(500, seed=2)
parts = dirichlet_partition(train_data["y"], args.clients, alpha=1.0, seed=0)
clients = make_client_fleet(train_data, parts, scenario="low")

cfg = CNNConfig("resnet_mini", "resnet", stage_sizes=(1, 1, 1),
                stage_channels=(16, 32, 64))
model = CNN(cfg)
params, state = model.init(jax.random.PRNGKey(0))

print("Eq.(4) stage memory model (batch 32):")
for s in range(3):
    mb = cnn_stage_memory_bytes(model, s, 32) / 2**20
    print(f"  stage {s}: {mb:7.1f} MiB")

def eval_fn(p, s, stage):
    logits, _ = model.apply(p, s, jnp.asarray(test["x"]), train=False)
    return float((jnp.argmax(logits, -1) == jnp.asarray(test["y"])).mean())

srv = SmartFreezeServer(model, clients, clients_per_round=6, local_epochs=1,
                        batch_size=32, rounds_per_stage=args.rounds_per_stage,
                        pace_kwargs=dict(min_rounds=4, mu=2, slope_lambda=2e-2))
out = srv.run(params, state, eval_fn=eval_fn, eval_every=2)
print(f"\n{out['rounds']} rounds:")
for rr in out["history"]:
    acc = f" acc={rr.test_acc:.3f}" if rr.test_acc is not None else ""
    frz = "  << FROZEN" if rr.frozen else ""
    print(f"  r{rr.round_idx:3d} stage{rr.stage} loss={rr.loss:.3f}{acc}{frz}")
