"""Quickstart: progressive (SmartFreeze) training of a reduced llama3-8b on
CPU in under a minute — stages train, the pace controller freezes them, the
model grows. See examples/federated_cifar.py for the paper's FL testbed and
examples/serve_decode.py for serving.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train

out = train("llama3-8b", reduced=True, steps=16, batch=4, seq=64,
            num_pods=1, lr=5e-3)
history = out["history"]
print()
for stage in sorted({h["stage"] for h in history}):
    ls = [h["loss"] for h in history if h["stage"] == stage]
    print(f"stage {stage}: loss {ls[0]:.3f} -> {ls[-1]:.3f} over {len(ls)} rounds")
    # each stage must improve its own objective (the output module is
    # re-initialized at stage boundaries, so cross-stage loss jumps are
    # expected — see the paper's Fig. 5 growth procedure)
    assert ls[-1] < ls[0] or len(ls) < 3, (stage, ls)
