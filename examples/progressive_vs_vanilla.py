"""Beyond-quickstart comparison: progressive SmartFreeze stages vs vanilla
full-model training on the same token budget — shows the FLOPs saving
(Eq. 5) at matched loss trajectory.

Run:  PYTHONPATH=src python examples/progressive_vs_vanilla.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax, jax.numpy as jnp
from repro import configs
from repro.core import freezing
from repro.core.memory_model import full_model_flops, stage_flops
from repro.data.synthetic import make_lm_batch
from repro.models.transformer import build
from repro.optim import adamw

cfg = configs.get("llama3-8b").reduced()
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {k: jnp.asarray(v) for k, v in make_lm_batch(cfg, 4, 64).items()}

for label, stage in [("vanilla (full model)", None),
                     ("SmartFreeze stage 0", 0),
                     ("SmartFreeze stage 1", 1)]:
    plan = freezing.make_stage_plan(cfg, stage)
    frozen, active = freezing.init_stage_active(model, params, plan,
                                                jax.random.PRNGKey(1))
    opt = adamw(3e-3)
    step = jax.jit(freezing.make_train_step(model, plan, opt, remat=False))
    st = freezing.TrainState(active, frozen, opt.init(active), jnp.int32(0))
    for _ in range(6):
        st, m = step(st, batch)
    fl = (full_model_flops(cfg, 4, 64) if stage is None
          else stage_flops(cfg, stage, 4, 64)["total"])
    print(f"{label:24s} loss={float(m['loss']):.4f}  step FLOPs={fl:.3e}")
