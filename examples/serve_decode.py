"""Serve a small model with batched requests: greedy decode over a KV cache.

Run:  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-7b
(any non-encoder arch id works; models are reduced-size for CPU)
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

main()
